//! Scoped-span tracing over monotonic clocks.
//!
//! The hot-path contract: when tracing is disabled (the default), entering
//! a span is one relaxed atomic load and nothing else — no allocation, no
//! clock read, no thread-local touch.  When enabled, each span records a
//! Begin/End event pair into a per-thread buffer that flushes into a
//! process-wide sink (on overflow and on thread exit), so instrumented
//! code never contends on a global lock per event.
//!
//! Span identity: ids come from one process-wide counter; each thread
//! keeps a stack of open span ids, so every event carries its parent id
//! and the exported trace is a forest.  `SpanGuard` is RAII — exits always
//! match enters and nesting is balanced per thread by construction (the
//! `prop_span_tree_well_formed` test pins this).
//!
//! Export: Chrome `trace_event` JSON (load in `chrome://tracing` or
//! Perfetto) or JSONL, chosen by file extension in [`export`].

use std::borrow::Cow;
use std::cell::RefCell;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    Begin,
    End,
}

/// One half of a span: a Begin or End mark on one thread.
#[derive(Debug, Clone)]
pub struct Event {
    pub name: Cow<'static, str>,
    pub phase: Phase,
    /// Process-unique span id (Begin and End share it).
    pub id: u64,
    /// Enclosing span's id; 0 for a root span.
    pub parent: u64,
    /// Process-local thread number (assigned on first span per thread).
    pub tid: u64,
    /// Nanoseconds since the process trace epoch.
    pub ts_ns: u64,
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static NEXT_SPAN_ID: AtomicU64 = AtomicU64::new(1);
static NEXT_TID: AtomicU64 = AtomicU64::new(1);

/// Per-thread buffer flushes into the sink at this size.
const FLUSH_AT: usize = 4096;

fn epoch() -> &'static Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    EPOCH.get_or_init(Instant::now)
}

fn now_ns() -> u64 {
    epoch().elapsed().as_nanos() as u64
}

fn sink() -> &'static Mutex<Vec<Event>> {
    static SINK: OnceLock<Mutex<Vec<Event>>> = OnceLock::new();
    SINK.get_or_init(|| Mutex::new(Vec::new()))
}

struct Local {
    tid: u64,
    stack: Vec<u64>,
    buf: Vec<Event>,
}

impl Local {
    fn flush(&mut self) {
        if self.buf.is_empty() {
            return;
        }
        // This runs from Drop during unwinding too — never re-panic on a
        // poisoned sink, just keep the events.
        sink().lock().unwrap_or_else(|e| e.into_inner()).append(&mut self.buf);
    }
}

impl Drop for Local {
    fn drop(&mut self) {
        // Worker threads flush their tail on exit, so a pool that has been
        // joined has published every event it recorded.
        self.flush();
    }
}

thread_local! {
    static LOCAL: RefCell<Local> = RefCell::new(Local {
        tid: NEXT_TID.fetch_add(1, Ordering::Relaxed),
        stack: Vec::new(),
        buf: Vec::new(),
    });
}

/// Whether spans currently record (one relaxed load — the entire disabled
/// cost of every instrumentation site).
#[inline(always)]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn tracing on (clears any previously buffered events so one export
/// corresponds to one enable..export window).
pub fn enable() {
    let _ = epoch();
    reset();
    ENABLED.store(true, Ordering::SeqCst);
}

/// Turn tracing off.  Open spans still record their End events (their
/// guards were armed at creation), so traces stay well-formed.
pub fn disable() {
    ENABLED.store(false, Ordering::SeqCst);
}

/// Drop all buffered events on the calling thread and in the sink.
pub fn reset() {
    LOCAL.with(|l| {
        let mut l = l.borrow_mut();
        l.buf.clear();
        l.stack.clear();
    });
    sink().lock().unwrap_or_else(|e| e.into_inner()).clear();
}

/// RAII span: records Begin on creation, End on drop.  Inert (zero work on
/// drop) when tracing was disabled at creation.
pub struct SpanGuard {
    name: Cow<'static, str>,
    id: u64,
    parent: u64,
    armed: bool,
}

/// Open a span with a static name (the common, allocation-light case).
#[inline]
pub fn span(name: &'static str) -> SpanGuard {
    if !enabled() {
        return SpanGuard { name: Cow::Borrowed(""), id: 0, parent: 0, armed: false };
    }
    span_cow(Cow::Borrowed(name))
}

/// Open a span whose name is built lazily — the closure only runs (and
/// allocates) when tracing is enabled.
#[inline]
pub fn span_with<F: FnOnce() -> String>(f: F) -> SpanGuard {
    if !enabled() {
        return SpanGuard { name: Cow::Borrowed(""), id: 0, parent: 0, armed: false };
    }
    span_cow(Cow::Owned(f()))
}

fn span_cow(name: Cow<'static, str>) -> SpanGuard {
    let id = NEXT_SPAN_ID.fetch_add(1, Ordering::Relaxed);
    LOCAL.with(|l| {
        let mut l = l.borrow_mut();
        let parent = l.stack.last().copied().unwrap_or(0);
        l.stack.push(id);
        let ev = Event {
            name: name.clone(),
            phase: Phase::Begin,
            id,
            parent,
            tid: l.tid,
            ts_ns: now_ns(),
        };
        l.buf.push(ev);
        if l.buf.len() >= FLUSH_AT {
            l.flush();
        }
        SpanGuard { name, id, parent, armed: true }
    })
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if !self.armed {
            return;
        }
        LOCAL.with(|l| {
            let mut l = l.borrow_mut();
            // Pop to (and including) our id; RAII drop order makes this a
            // single pop, the loop only guards against exotic guard moves.
            while let Some(top) = l.stack.pop() {
                if top == self.id {
                    break;
                }
            }
            let ev = Event {
                name: std::mem::replace(&mut self.name, Cow::Borrowed("")),
                phase: Phase::End,
                id: self.id,
                parent: self.parent,
                tid: l.tid,
                ts_ns: now_ns(),
            };
            l.buf.push(ev);
            if l.buf.len() >= FLUSH_AT {
                l.flush();
            }
        });
    }
}

/// Flush the calling thread's buffer and return every event recorded so
/// far, sorted by timestamp.  Other *live* threads' unflushed tails are
/// not included — join workers before exporting (the pool shutdown paths
/// already do; thread exit flushes automatically).
pub fn snapshot() -> Vec<Event> {
    LOCAL.with(|l| l.borrow_mut().flush());
    let mut evs = sink().lock().unwrap_or_else(|e| e.into_inner()).clone();
    evs.sort_by_key(|e| e.ts_ns);
    evs
}

/// Export the current snapshot; `.jsonl` extension selects JSONL, anything
/// else Chrome `trace_event` JSON.
pub fn export(path: &Path) -> anyhow::Result<()> {
    let jsonl = path.extension().and_then(|e| e.to_str()) == Some("jsonl");
    if jsonl {
        export_jsonl(path)
    } else {
        export_chrome(path)
    }
}

fn escape(name: &str) -> String {
    // Span names are ascii identifiers by convention; escape defensively.
    name.chars()
        .flat_map(|c| match c {
            '"' | '\\' => vec!['\\', c],
            c if (c as u32) < 0x20 => vec![' '],
            c => vec![c],
        })
        .collect()
}

/// Chrome `trace_event` format: `{"traceEvents": [{"ph": "B"|"E", ...}]}`
/// with microsecond timestamps.
pub fn export_chrome(path: &Path) -> anyhow::Result<()> {
    let evs = snapshot();
    let mut out = String::with_capacity(64 + evs.len() * 96);
    out.push_str("{\"traceEvents\":[");
    for (i, e) in evs.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let ph = match e.phase {
            Phase::Begin => 'B',
            Phase::End => 'E',
        };
        out.push_str(&format!(
            "{{\"name\":\"{}\",\"ph\":\"{}\",\"ts\":{:.3},\"pid\":1,\"tid\":{},\"args\":{{\"id\":{},\"parent\":{}}}}}",
            escape(&e.name),
            ph,
            e.ts_ns as f64 / 1e3,
            e.tid,
            e.id,
            e.parent
        ));
    }
    out.push_str("]}\n");
    std::fs::write(path, out)
        .map_err(|e| anyhow::anyhow!("writing trace {}: {e}", path.display()))
}

/// JSONL: one event object per line, nanosecond timestamps.
pub fn export_jsonl(path: &Path) -> anyhow::Result<()> {
    let evs = snapshot();
    let mut out = String::with_capacity(evs.len() * 96);
    for e in &evs {
        let ph = match e.phase {
            Phase::Begin => "B",
            Phase::End => "E",
        };
        out.push_str(&format!(
            "{{\"name\":\"{}\",\"ph\":\"{}\",\"id\":{},\"parent\":{},\"tid\":{},\"ts_ns\":{}}}\n",
            escape(&e.name),
            ph,
            e.id,
            e.parent,
            e.tid,
            e.ts_ns
        ));
    }
    std::fs::write(path, out)
        .map_err(|e| anyhow::anyhow!("writing trace {}: {e}", path.display()))
}

/// Check Begin/End well-formedness of `events` per thread: every End
/// matches the most recent open Begin with the same id (proper nesting),
/// no End without a Begin, and nothing left open.  Returns a description
/// of the first violation.  Used by tests; exported events additionally
/// get timestamp-sorted, which preserves per-thread order (buffers are
/// appended in record order and timestamps are monotonic per thread).
pub fn check_well_formed(events: &[Event]) -> Result<(), String> {
    use std::collections::BTreeMap;
    let mut stacks: BTreeMap<u64, Vec<(u64, String)>> = BTreeMap::new();
    for e in events {
        let stack = stacks.entry(e.tid).or_default();
        match e.phase {
            Phase::Begin => stack.push((e.id, e.name.to_string())),
            Phase::End => match stack.pop() {
                None => return Err(format!("End `{}` (id {}) with empty stack", e.name, e.id)),
                Some((id, name)) => {
                    if id != e.id {
                        return Err(format!(
                            "End `{}` (id {}) crosses open span `{name}` (id {id})",
                            e.name, e.id
                        ));
                    }
                }
            },
        }
    }
    for (tid, stack) in &stacks {
        if let Some((id, name)) = stack.last() {
            return Err(format!("span `{name}` (id {id}) left open on tid {tid}"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    // Tracing state is process-global and `cargo test` is parallel:
    // every test that toggles it holds this lock.
    fn test_lock() -> std::sync::MutexGuard<'static, ()> {
        static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
        LOCK.get_or_init(|| Mutex::new(())).lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn spans_record_pairs_and_disable_is_inert() {
        let _guard = test_lock();
        enable();
        {
            let _a = span("outer");
            {
                let _b = span_with(|| format!("inner_{}", 1));
            }
        }
        // A worker thread's events flush on thread exit.
        std::thread::spawn(|| {
            let _w = span("worker");
        })
        .join()
        .unwrap();
        disable();
        let evs = snapshot();
        // Other tests may run instrumented code concurrently; only judge
        // the events this test owns (names are unique to it).
        let named: Vec<Event> = evs
            .iter()
            .filter(|e| e.name == "outer" || e.name == "inner_1" || e.name == "worker")
            .cloned()
            .collect();
        assert_eq!(named.len(), 6, "3 spans -> 6 events, got {named:?}");
        let outer_b = named.iter().find(|e| e.name == "outer" && e.phase == Phase::Begin).unwrap();
        let inner_b = named.iter().find(|e| e.name == "inner_1" && e.phase == Phase::Begin).unwrap();
        assert_eq!(inner_b.parent, outer_b.id, "inner span's parent is the enclosing span");
        let worker_b = named.iter().find(|e| e.name == "worker" && e.phase == Phase::Begin).unwrap();
        assert_eq!(worker_b.parent, 0, "worker span is a root on its thread");
        assert_ne!(worker_b.tid, outer_b.tid);
        check_well_formed(&named).unwrap();

        reset();
        // Disabled spans do nothing — no events, no ids burned on the sink.
        {
            let _c = span("disabled");
        }
        assert!(snapshot().iter().all(|e| e.name != "disabled"));
    }

    #[test]
    fn prop_span_tree_well_formed() {
        let _guard = test_lock();
        enable();
        // Unique name prefix per property case so concurrent instrumented
        // tests (and shrink re-runs) can't contaminate the filtered view.
        static CASE: AtomicU64 = AtomicU64::new(0);

        fn build(prefix: &str, label: usize, depth: usize) {
            let _s = span_with(|| format!("{prefix}{label}_{depth}"));
            if depth > 0 {
                build(prefix, label, depth - 1);
            }
        }

        crate::util::prop::check(
            "span_tree_well_formed",
            32,
            |r| {
                let n = r.below(8);
                (0..n).map(|_| r.below(4)).collect::<Vec<usize>>()
            },
            |script| {
                let case = CASE.fetch_add(1, Ordering::Relaxed);
                let prefix = format!("prop_{case}_");
                for (i, &d) in script.iter().enumerate() {
                    build(&prefix, i, d);
                }
                let evs: Vec<Event> = snapshot()
                    .into_iter()
                    .filter(|e| e.name.starts_with(&prefix))
                    .collect();
                let expected = 2 * script.iter().map(|d| d + 1).sum::<usize>();
                if evs.len() != expected {
                    return Err(format!("expected {expected} events, got {}", evs.len()));
                }
                check_well_formed(&evs)?;
                // Every non-root parent must itself be a Begin in this case's
                // forest — parents never dangle.
                let ids: std::collections::BTreeSet<u64> = evs.iter().map(|e| e.id).collect();
                for e in &evs {
                    if e.parent != 0 && !ids.contains(&e.parent) {
                        return Err(format!("event `{}` has dangling parent {}", e.name, e.parent));
                    }
                }
                Ok(())
            },
        );
        disable();
        reset();
    }

    #[test]
    fn exports_write_loadable_files() {
        let _guard = test_lock();
        let dir = std::env::temp_dir().join(format!("coc_trace_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        enable();
        {
            let _s = span("export_me");
        }
        disable();
        let chrome = dir.join("t.json");
        export(&chrome).unwrap();
        let text = std::fs::read_to_string(&chrome).unwrap();
        let parsed = crate::util::json::Json::parse(&text).unwrap();
        let evs = parsed.req("traceEvents").unwrap().as_arr().unwrap();
        assert!(evs
            .iter()
            .any(|e| e.get("name").and_then(|n| n.as_str()) == Some("export_me")));
        let jsonl = dir.join("t.jsonl");
        export(&jsonl).unwrap();
        let text = std::fs::read_to_string(&jsonl).unwrap();
        assert!(text.lines().count() >= 2);
        for line in text.lines() {
            crate::util::json::Json::parse(line).unwrap();
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
