//! The committed bench ledger: `BENCH_<area>.json` files at the repo root
//! plus the comparison logic behind `coc bench-diff`.
//!
//! Each per-run result file under `results/` is a point measurement; the
//! ledger is the *committed trajectory* — the blessed numbers CI refuses
//! to regress.  An area file holds a schema version, the source results
//! file it was distilled from, and a list of metrics, each with a
//! direction (`higher`/`lower` is better) and a tolerance in percent.
//! Byte-accounting metrics get tight tolerances (they are deterministic);
//! wall-clock metrics get loose ones (CI runners vary).
//!
//! `coc bench-diff` extracts the same metrics from the current `results/`
//! files, compares against the committed entries, prints a table, and
//! exits nonzero if any metric regressed beyond its tolerance.
//! `coc bench-diff --update` rewrites the ledger from the current run
//! (the "bless" operation, reviewed like any other diff).

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::json::{self, Json};

/// Bump when the `BENCH_*.json` layout changes; readers reject files with
/// a different major version rather than mis-parsing them.
pub const BENCH_SCHEMA_VERSION: u64 = 1;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    /// Bigger is better (throughput, steps/sec, speedups).
    Higher,
    /// Smaller is better (latency, bytes moved).
    Lower,
}

impl Direction {
    pub fn name(self) -> &'static str {
        match self {
            Direction::Higher => "higher",
            Direction::Lower => "lower",
        }
    }

    pub fn parse(s: &str) -> Option<Direction> {
        match s {
            "higher" => Some(Direction::Higher),
            "lower" => Some(Direction::Lower),
            _ => None,
        }
    }
}

#[derive(Debug, Clone)]
pub struct MetricEntry {
    pub name: String,
    pub value: f64,
    pub direction: Direction,
    /// Allowed regression before `bench-diff` fails, in percent of the
    /// committed value.
    pub tol_pct: f64,
}

/// One ledger area (one committed `BENCH_<area>.json`).
#[derive(Debug, Clone)]
pub struct BenchArea {
    pub area: String,
    /// The results file this area distills, repo-root-relative.
    pub source: String,
    pub metrics: Vec<MetricEntry>,
}

impl BenchArea {
    pub fn metric(&self, name: &str) -> Option<&MetricEntry> {
        self.metrics.iter().find(|m| m.name == name)
    }

    pub fn to_json(&self) -> Json {
        let metrics: Vec<Json> = self
            .metrics
            .iter()
            .map(|m| {
                json::obj(vec![
                    ("name", json::s(&m.name)),
                    ("value", json::num(m.value)),
                    ("direction", json::s(m.direction.name())),
                    ("tol_pct", json::num(m.tol_pct)),
                ])
            })
            .collect();
        json::obj(vec![
            ("schema_version", json::num(BENCH_SCHEMA_VERSION as f64)),
            ("area", json::s(&self.area)),
            ("source", json::s(&self.source)),
            ("metrics", Json::Arr(metrics)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<BenchArea> {
        let version = j
            .req("schema_version")?
            .as_f64()
            .ok_or_else(|| anyhow!("schema_version is not a number"))? as u64;
        if version != BENCH_SCHEMA_VERSION {
            return Err(anyhow!(
                "bench ledger schema_version {version} (this build reads {BENCH_SCHEMA_VERSION})"
            ));
        }
        let area = j.req("area")?.as_str().ok_or_else(|| anyhow!("area is not a string"))?;
        let source = j.req("source")?.as_str().unwrap_or_default();
        let mut metrics = Vec::new();
        for m in j.req("metrics")?.as_arr().ok_or_else(|| anyhow!("metrics is not an array"))? {
            let name = m.req("name")?.as_str().ok_or_else(|| anyhow!("metric name"))?;
            let value =
                m.req("value")?.as_f64().ok_or_else(|| anyhow!("metric `{name}` value"))?;
            let dir = m.req("direction")?.as_str().and_then(Direction::parse).ok_or_else(
                || anyhow!("metric `{name}`: direction must be `higher` or `lower`"),
            )?;
            let tol = m.get("tol_pct").and_then(|t| t.as_f64()).unwrap_or(50.0);
            metrics.push(MetricEntry {
                name: name.to_string(),
                value,
                direction: dir,
                tol_pct: tol,
            });
        }
        Ok(BenchArea { area: area.to_string(), source: source.to_string(), metrics })
    }

    pub fn load(path: &Path) -> Result<BenchArea> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading bench ledger {}", path.display()))?;
        let j = Json::parse(&text)
            .map_err(|e| anyhow!("parsing bench ledger {}: {e}", path.display()))?;
        Self::from_json(&j).with_context(|| format!("in {}", path.display()))
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, format!("{}\n", self.to_json()))
            .with_context(|| format!("writing bench ledger {}", path.display()))
    }
}

/// The ledger areas this repo tracks.
pub fn areas() -> &'static [&'static str] {
    &["serve", "serve_compressed", "refback", "refback_kernels"]
}

/// Repo-root file name for an area.
pub fn ledger_path(root: &Path, area: &str) -> PathBuf {
    root.join(format!("BENCH_{area}.json"))
}

// ----- extraction: results/*.json -> a fresh BenchArea ----------------------

fn load_results(results_dir: &Path, file: &str) -> Result<Json> {
    let path = results_dir.join(file);
    let text = std::fs::read_to_string(&path).with_context(|| {
        format!(
            "reading {} (run the producing bench/command first — see DESIGN.md \
             \"Observability\")",
            path.display()
        )
    })?;
    Json::parse(&text).map_err(|e| anyhow!("parsing {}: {e}", path.display()))
}

fn pull(j: &Json, path: &[&str]) -> Result<f64> {
    let mut cur = j;
    for k in path {
        cur = cur.req(k)?;
    }
    cur.as_f64().ok_or_else(|| anyhow!("field `{}` is not a number", path.join(".")))
}

/// Distill the current `results/` files into a fresh area entry (the
/// "current" side of a diff, and the payload `--update` commits).
pub fn extract(area: &str, results_dir: &Path) -> Result<BenchArea> {
    let entry = |name: &str, value: f64, direction: Direction, tol_pct: f64| MetricEntry {
        name: name.to_string(),
        value,
        direction,
        tol_pct,
    };
    match area {
        "serve" => {
            let j = load_results(results_dir, "serve_bench.json")?;
            let up = pull(&j, &["bytes_uploaded"]).unwrap_or(0.0);
            let down = pull(&j, &["bytes_downloaded"]).unwrap_or(0.0);
            Ok(BenchArea {
                area: "serve".into(),
                source: "results/serve_bench.json".into(),
                metrics: vec![
                    entry(
                        "throughput_rps",
                        pull(&j, &["bench", "throughput_rps"])?,
                        Direction::Higher,
                        60.0,
                    ),
                    entry(
                        "p50_us",
                        pull(&j, &["bench", "latency", "p50_us"])?,
                        Direction::Lower,
                        60.0,
                    ),
                    entry(
                        "p95_us",
                        pull(&j, &["bench", "latency", "p95_us"])?,
                        Direction::Lower,
                        60.0,
                    ),
                    // Transfer volume is deterministic — tight tolerance.
                    entry("bytes_moved", up + down, Direction::Lower, 5.0),
                ],
            })
        }
        "refback" => {
            let j = load_results(results_dir, "refback_kernels.json")?;
            Ok(BenchArea {
                area: "refback".into(),
                source: "results/refback_kernels.json".into(),
                metrics: vec![
                    entry(
                        "train_steps_per_sec_1t",
                        pull(&j, &["train_steps_per_sec_1t"])?,
                        Direction::Higher,
                        60.0,
                    ),
                    entry(
                        "train_steps_per_sec_4t",
                        pull(&j, &["train_steps_per_sec_4t"])?,
                        Direction::Higher,
                        60.0,
                    ),
                    entry(
                        "conv_fwd_blocked_1t_ms",
                        pull(&j, &["conv_fwd_blocked_1t_ms"])?,
                        Direction::Lower,
                        60.0,
                    ),
                    entry(
                        "conv_bwd_blocked_1t_ms",
                        pull(&j, &["conv_bwd_blocked_1t_ms"])?,
                        Direction::Lower,
                        60.0,
                    ),
                    entry(
                        "matmul_blocked_us",
                        pull(&j, &["matmul_blocked_us"])?,
                        Direction::Lower,
                        60.0,
                    ),
                ],
            })
        }
        "serve_compressed" => {
            let j = load_results(results_dir, "serve_bench_compressed.json")?;
            Ok(BenchArea {
                area: "serve_compressed".into(),
                source: "results/serve_bench_compressed.json".into(),
                metrics: vec![
                    // The compressed-vs-dense rps ratio is the headline:
                    // both sides ran the same pool and load in the same
                    // process, so it is far steadier than raw rps.
                    entry("speedup", pull(&j, &["speedup"])?, Direction::Higher, 20.0),
                    entry(
                        "throughput_rps",
                        pull(&j, &["compressed", "throughput_rps"])?,
                        Direction::Higher,
                        60.0,
                    ),
                    // Packed/dense model bytes are deterministic.
                    entry("bytes_ratio", pull(&j, &["bytes_ratio"])?, Direction::Lower, 5.0),
                ],
            })
        }
        "refback_kernels" => {
            let j = load_results(results_dir, "refback_kernels.json")?;
            Ok(BenchArea {
                area: "refback_kernels".into(),
                source: "results/refback_kernels.json".into(),
                metrics: vec![
                    entry(
                        "eval_compressed_speedup",
                        pull(&j, &["eval_compressed_speedup"])?,
                        Direction::Higher,
                        20.0,
                    ),
                    entry(
                        "eval_compressed_sps",
                        pull(&j, &["eval_compressed_sps"])?,
                        Direction::Higher,
                        60.0,
                    ),
                    entry("bytes_ratio", pull(&j, &["bytes_ratio"])?, Direction::Lower, 5.0),
                    // Scalar-vs-SIMD ratios: same process, same operands —
                    // steadier than raw latency, but still wall-clock.
                    entry(
                        "simd_speedup_conv_fwd",
                        pull(&j, &["simd_speedup_conv_fwd"])?,
                        Direction::Higher,
                        40.0,
                    ),
                    entry(
                        "simd_speedup_matmul",
                        pull(&j, &["simd_speedup_matmul"])?,
                        Direction::Higher,
                        40.0,
                    ),
                ],
            })
        }
        other => Err(anyhow!("unknown bench area `{other}` (have: {})", areas().join(", "))),
    }
}

// ----- diffing --------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct DiffLine {
    pub name: String,
    pub baseline: f64,
    pub current: f64,
    /// Regression in percent of the committed value: positive = worse,
    /// negative = improved (sign-normalized across directions).
    pub regression_pct: f64,
    pub tol_pct: f64,
    pub regressed: bool,
}

/// Compare `current` against the committed `baseline`.  Only metrics
/// present in the baseline are judged (a new metric can't regress);
/// `tol_override` replaces every per-metric tolerance when set (the CLI
/// `--threshold` flag).
pub fn diff(baseline: &BenchArea, current: &BenchArea, tol_override: Option<f64>) -> Vec<DiffLine> {
    let mut out = Vec::new();
    for base in &baseline.metrics {
        let Some(cur) = current.metric(&base.name) else {
            continue;
        };
        let tol = tol_override.unwrap_or(base.tol_pct);
        let regression_pct = if base.value == 0.0 {
            if cur.value == base.value {
                0.0
            } else {
                match base.direction {
                    // Anything above a committed zero (e.g. bytes moved on
                    // a zero-transfer backend) is an unbounded regression.
                    Direction::Lower => f64::INFINITY,
                    Direction::Higher => -100.0,
                }
            }
        } else {
            match base.direction {
                Direction::Lower => (cur.value - base.value) / base.value * 100.0,
                Direction::Higher => (base.value - cur.value) / base.value * 100.0,
            }
        };
        out.push(DiffLine {
            name: base.name.clone(),
            baseline: base.value,
            current: cur.value,
            regression_pct,
            tol_pct: tol,
            regressed: regression_pct > tol,
        });
    }
    out
}

/// Human-readable diff table (one line per metric).
pub fn format_table(area: &str, lines: &[DiffLine]) -> String {
    let mut out = format!("bench-diff [{area}]\n");
    for l in lines {
        let status = if l.regressed {
            "REGRESSED"
        } else if l.regression_pct < 0.0 {
            "improved"
        } else {
            "ok"
        };
        out.push_str(&format!(
            "  {:<28} committed {:>12.3}  current {:>12.3}  change {:>+8.1}%  (tol {:.0}%)  {status}\n",
            l.name, l.baseline, l.current, l.regression_pct, l.tol_pct
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn area_with(p95: f64, rps: f64) -> BenchArea {
        BenchArea {
            area: "serve".into(),
            source: "results/serve_bench.json".into(),
            metrics: vec![
                MetricEntry {
                    name: "p95_us".into(),
                    value: p95,
                    direction: Direction::Lower,
                    tol_pct: 50.0,
                },
                MetricEntry {
                    name: "throughput_rps".into(),
                    value: rps,
                    direction: Direction::Higher,
                    tol_pct: 50.0,
                },
            ],
        }
    }

    #[test]
    fn flags_a_2x_latency_regression() {
        // The acceptance scenario: synthetically double p95 -> nonzero.
        let base = area_with(1000.0, 500.0);
        let cur = area_with(2000.0, 500.0);
        let d = diff(&base, &cur, None);
        let p95 = d.iter().find(|l| l.name == "p95_us").unwrap();
        assert!(p95.regressed, "2x latency must exceed a 50% tolerance");
        assert!((p95.regression_pct - 100.0).abs() < 1e-9);
        let rps = d.iter().find(|l| l.name == "throughput_rps").unwrap();
        assert!(!rps.regressed);
    }

    #[test]
    fn improvements_and_small_noise_pass() {
        let base = area_with(1000.0, 500.0);
        // 20% faster latency, 10% lower throughput: both within 50%.
        let cur = area_with(800.0, 450.0);
        let d = diff(&base, &cur, None);
        assert!(d.iter().all(|l| !l.regressed), "{d:?}");
        let p95 = d.iter().find(|l| l.name == "p95_us").unwrap();
        assert!(p95.regression_pct < 0.0, "faster latency reads as improvement");
        // A strict override threshold turns the 10% throughput drop fatal.
        let d = diff(&base, &cur, Some(5.0));
        assert!(d.iter().find(|l| l.name == "throughput_rps").unwrap().regressed);
    }

    #[test]
    fn zero_baseline_bytes_gate() {
        let base = BenchArea {
            area: "serve".into(),
            source: String::new(),
            metrics: vec![MetricEntry {
                name: "bytes_moved".into(),
                value: 0.0,
                direction: Direction::Lower,
                tol_pct: 5.0,
            }],
        };
        let mut cur = base.clone();
        let d = diff(&base, &cur, None);
        assert!(!d[0].regressed, "0 -> 0 is clean");
        cur.metrics[0].value = 4096.0;
        let d = diff(&base, &cur, None);
        assert!(d[0].regressed, "any bytes over a zero-transfer baseline regress");
    }

    #[test]
    fn ledger_json_roundtrip_and_version_gate() {
        let a = area_with(1234.5, 678.9);
        let j = a.to_json();
        let back = BenchArea::from_json(&j).unwrap();
        assert_eq!(back.area, a.area);
        assert_eq!(back.metrics.len(), a.metrics.len());
        assert_eq!(back.metric("p95_us").unwrap().value, 1234.5);
        assert_eq!(back.metric("p95_us").unwrap().direction, Direction::Lower);

        // A future schema version must be rejected, not mis-read.
        let text = j.to_string().replace("\"schema_version\":1", "\"schema_version\":999");
        let j2 = Json::parse(&text).unwrap();
        assert!(BenchArea::from_json(&j2).is_err());
    }

    #[test]
    fn extract_reads_serve_results() {
        let dir = std::env::temp_dir().join(format!("coc_ledger_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let body = r#"{"bench": {"throughput_rps": 321.0, "latency": {"p50_us": 900.0, "p95_us": 2500.0}}, "bytes_uploaded": 10, "bytes_downloaded": 22}"#;
        std::fs::write(dir.join("serve_bench.json"), body).unwrap();
        let a = extract("serve", &dir).unwrap();
        assert_eq!(a.metric("throughput_rps").unwrap().value, 321.0);
        assert_eq!(a.metric("p95_us").unwrap().value, 2500.0);
        assert_eq!(a.metric("bytes_moved").unwrap().value, 32.0);
        assert!(extract("nope", &dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
