//! Minimal JSON parser / writer (serde is unavailable offline).
//!
//! Supports the full JSON grammar needed by `artifacts/manifest.json` and
//! the result emitters: objects, arrays, strings (with escapes), numbers,
//! booleans, null.  Numbers are stored as f64 (ints round-trip exactly up
//! to 2^53, far beyond anything in a manifest).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, ParseError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ----- typed accessors (all return Option; callers decide strictness) --

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| if n >= 0.0 { Some(n as usize) } else { None })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Required-field helper with a readable error.
    pub fn req<'a>(&'a self, key: &str) -> anyhow::Result<&'a Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json field `{key}`"))
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.i, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.i += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.i = self.i.saturating_sub(1);
            Err(self.err(&format!("expected `{}`", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{s}`")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a json value")),
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let mut cp = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            cp = cp * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad \\u"))?;
                        }
                        // Surrogate pairs.
                        if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("lone surrogate"));
                            }
                            let mut lo = 0u32;
                            for _ in 0..4 {
                                let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                                lo = lo * 16
                                    + (c as char).to_digit(16).ok_or_else(|| self.err("bad \\u"))?;
                            }
                            cp = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                        }
                        s.push(char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences byte-wise.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        if start + len > self.b.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let chunk = std::str::from_utf8(&self.b[start..start + len])
                            .map_err(|_| self.err("bad utf-8"))?;
                        s.push_str(chunk);
                        self.i = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

// ----- writer ---------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

/// Convenience constructors for result emitters.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        let src = r#"{"a":[1,2.5,-3e2],"b":{"c":true,"d":null},"e":"x\n\"y\""}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"n": 3, "s": "hi", "a": [1, 2]}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_usize(), Some(3));
        assert_eq!(v.get("s").unwrap().as_str(), Some("hi"));
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 2);
        assert!(v.get("zz").is_none());
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str(), Some("é😀"));
    }

    #[test]
    fn utf8_passthrough() {
        let v = Json::parse("\"héllo — ok\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo — ok"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn nested_deep() {
        let v = Json::parse("[[[[[[1]]]]]]").unwrap();
        assert_eq!(
            v.idx(0).and_then(|v| v.idx(0)).and_then(|v| v.idx(0))
                .and_then(|v| v.idx(0)).and_then(|v| v.idx(0)).and_then(|v| v.idx(0))
                .and_then(|v| v.as_i64()),
            Some(1)
        );
    }
}
