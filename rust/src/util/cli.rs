//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Grammar: `coc <subcommand> [positional ...] [--flag] [--key value]`.
//! `--key=value` is also accepted.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

pub const FLAG_SET: &str = "true";

impl Args {
    pub fn parse_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    pub fn parse<I: IntoIterator<Item = String>>(it: I) -> Args {
        let mut out = Args::default();
        let mut iter = it.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.flags.insert(name.to_string(), v);
                } else {
                    out.flags.insert(name.to_string(), FLAG_SET.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects an integer, got `{v}`")),
        }
    }

    /// Integer flag with a lower bound — for knobs like `--jobs` where 0
    /// is a configuration error, not a request for zero workers.
    pub fn get_usize_min(&self, name: &str, default: usize, min: usize) -> anyhow::Result<usize> {
        let v = self.get_usize(name, default)?;
        if v < min {
            return Err(anyhow::anyhow!("--{name} must be >= {min}, got {v}"));
        }
        Ok(v)
    }

    pub fn get_f32(&self, name: &str, default: f32) -> anyhow::Result<f32> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects a number, got `{v}`")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects a number, got `{v}`")),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> anyhow::Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects an integer, got `{v}`")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string()))
    }

    #[test]
    fn subcommand_and_positional() {
        let a = parse(&["exp", "fig6", "extra"]);
        assert_eq!(a.subcommand.as_deref(), Some("exp"));
        assert_eq!(a.positional, vec!["fig6", "extra"]);
    }

    #[test]
    fn flags_with_values() {
        let a = parse(&["train", "--arch", "mini_vgg", "--steps=100", "--verbose"]);
        assert_eq!(a.get("arch"), Some("mini_vgg"));
        assert_eq!(a.get_usize("steps", 0).unwrap(), 100);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn flag_before_positional() {
        let a = parse(&["exp", "--out", "results", "fig6"]);
        assert_eq!(a.get("out"), Some("results"));
        assert_eq!(a.positional, vec!["fig6"]);
    }

    #[test]
    fn numeric_errors() {
        let a = parse(&["x", "--steps", "abc"]);
        assert!(a.get_usize("steps", 1).is_err());
    }

    #[test]
    fn usize_min_enforces_bound() {
        let a = parse(&["x", "--jobs", "0"]);
        assert!(a.get_usize_min("jobs", 1, 1).is_err());
        let a = parse(&["x", "--jobs", "4"]);
        assert_eq!(a.get_usize_min("jobs", 1, 1).unwrap(), 4);
        let a = parse(&["x"]);
        assert_eq!(a.get_usize_min("jobs", 1, 1).unwrap(), 1);
    }

    #[test]
    fn defaults() {
        let a = parse(&["x"]);
        assert_eq!(a.get_or("scale", "default"), "default");
        assert_eq!(a.get_f32("lr", 0.05).unwrap(), 0.05);
        assert_eq!(a.get_f64("rate", 500.0).unwrap(), 500.0);
    }

    #[test]
    fn f64_parses_and_errors() {
        let a = parse(&["x", "--rate", "123.5", "--slo-ms", "oops"]);
        assert_eq!(a.get_f64("rate", 0.0).unwrap(), 123.5);
        assert!(a.get_f64("slo-ms", 50.0).is_err());
    }
}
