//! Deterministic PRNG (PCG64-DXSM style) — the `rand` crate is unavailable
//! offline.  Every stochastic component (data generation, shuffling, sweep
//! jitter, property tests) takes an explicit seeded `Rng` so experiments
//! replay bit-identically.

#[derive(Clone, Debug)]
pub struct Rng {
    state: u128,
    inc: u128,
}

const MUL: u128 = 0x2360ed051fc65da44385df649fccf645;

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut r = Rng {
            state: (seed as u128) << 64 | 0x9e3779b97f4a7c15,
            inc: ((seed as u128).wrapping_mul(0xda942042e4dd58b5)) | 1,
        };
        // Scramble the trivially-related initial state.
        for _ in 0..4 {
            r.next_u64();
        }
        r
    }

    /// Derive an independent stream (for parallel / nested use).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9e3779b97f4a7c15))
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(MUL).wrapping_add(self.inc);
        // DXSM output permutation.
        let mut hi = (self.state >> 64) as u64;
        let lo = (self.state as u64) | 1;
        hi ^= hi >> 32;
        hi = hi.wrapping_mul(0xda942042e4dd58b5);
        hi ^= hi >> 48;
        hi.wrapping_mul(lo)
    }

    /// Uniform in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform in [lo, hi).
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire rejection-free-enough reduction.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal (Box–Muller).
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.f32();
            if u1 > 1e-7 {
                let u2 = self.f32();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (2.0 * std::f32::consts::PI * u2).cos();
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// Sample k distinct indices from [0, n) (k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            seen[r.below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
