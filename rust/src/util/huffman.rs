//! Canonical Huffman coding — the third stage of Deep Compression (Han et
//! al. 2015), one of the combination baselines the paper compares against.
//! Used by the `HuffmanCoding` chain stage to measure the entropy-coded
//! storage of clustered / quantized weights.

use std::collections::BinaryHeap;

/// Code-length assignment for each symbol (0 = symbol absent).
#[derive(Debug, Clone)]
pub struct HuffmanCode {
    pub lengths: Vec<u8>,
}

impl HuffmanCode {
    /// Build from symbol frequencies.
    pub fn from_freqs(freqs: &[u64]) -> HuffmanCode {
        #[derive(PartialEq, Eq)]
        struct Node {
            weight: u64,
            id: usize, // tie-break for determinism
        }
        impl Ord for Node {
            fn cmp(&self, other: &Self) -> std::cmp::Ordering {
                // Min-heap via reversed compare.
                other.weight.cmp(&self.weight).then(other.id.cmp(&self.id))
            }
        }
        impl PartialOrd for Node {
            fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
                Some(self.cmp(other))
            }
        }

        let present: Vec<usize> = (0..freqs.len()).filter(|&i| freqs[i] > 0).collect();
        let mut lengths = vec![0u8; freqs.len()];
        match present.len() {
            0 => return HuffmanCode { lengths },
            1 => {
                lengths[present[0]] = 1;
                return HuffmanCode { lengths };
            }
            _ => {}
        }

        // parent pointers over a forest of (symbols + internal nodes).
        let mut parent: Vec<usize> = vec![usize::MAX; present.len() * 2 - 1];
        let mut heap: BinaryHeap<Node> = present
            .iter()
            .enumerate()
            .map(|(slot, &sym)| Node { weight: freqs[sym], id: slot })
            .collect();
        let mut next_id = present.len();
        while heap.len() > 1 {
            let a = heap.pop().unwrap();
            let b = heap.pop().unwrap();
            parent[a.id] = next_id;
            parent[b.id] = next_id;
            heap.push(Node { weight: a.weight + b.weight, id: next_id });
            next_id += 1;
        }
        for (slot, &sym) in present.iter().enumerate() {
            let mut depth = 0u8;
            let mut n = slot;
            while parent[n] != usize::MAX {
                n = parent[n];
                depth += 1;
            }
            lengths[sym] = depth.max(1);
        }
        HuffmanCode { lengths }
    }

    /// Total coded size in bits for the given frequencies.
    pub fn coded_bits(&self, freqs: &[u64]) -> u64 {
        freqs
            .iter()
            .zip(&self.lengths)
            .map(|(&f, &l)| f * l as u64)
            .sum()
    }

    /// Codebook side-information cost: one length byte per possible symbol
    /// plus the symbol-value table (32-bit values), canonical coding.
    pub fn table_bits(&self) -> u64 {
        let present = self.lengths.iter().filter(|&&l| l > 0).count() as u64;
        8 * self.lengths.len() as u64 + 32 * present
    }
}

/// Shannon entropy (bits/symbol) of a frequency table — the lower bound
/// Huffman approaches; used in tests and reports.
pub fn entropy_bits(freqs: &[u64]) -> f64 {
    let total: u64 = freqs.iter().sum();
    if total == 0 {
        return 0.0;
    }
    let mut h = 0.0;
    for &f in freqs {
        if f > 0 {
            let p = f as f64 / total as f64;
            h -= p * p.log2();
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classic_example() {
        // freqs for 4 symbols: skewed -> shorter code for frequent symbol.
        let freqs = [45u64, 13, 12, 30];
        let code = HuffmanCode::from_freqs(&freqs);
        assert!(code.lengths[0] <= code.lengths[1]);
        assert!(code.lengths[0] <= code.lengths[2]);
        // Kraft inequality (complete codes satisfy equality <= 1).
        let kraft: f64 = code
            .lengths
            .iter()
            .filter(|&&l| l > 0)
            .map(|&l| 2f64.powi(-(l as i32)))
            .sum();
        assert!(kraft <= 1.0 + 1e-9, "kraft {kraft}");
    }

    #[test]
    fn beats_or_matches_fixed_width_on_skew() {
        let freqs = [1000u64, 10, 10, 10, 5, 5, 3, 2];
        let code = HuffmanCode::from_freqs(&freqs);
        let coded = code.coded_bits(&freqs);
        let fixed = 3 * freqs.iter().sum::<u64>(); // 3 bits for 8 symbols
        assert!(coded < fixed, "huffman {coded} vs fixed {fixed}");
    }

    #[test]
    fn within_one_bit_of_entropy() {
        let freqs = [7u64, 21, 2, 40, 9, 1, 0, 13];
        let code = HuffmanCode::from_freqs(&freqs);
        let total: u64 = freqs.iter().sum();
        let avg = code.coded_bits(&freqs) as f64 / total as f64;
        let h = entropy_bits(&freqs);
        assert!(avg >= h - 1e-9, "avg {avg} below entropy {h}");
        assert!(avg < h + 1.0, "avg {avg} not within 1 bit of entropy {h}");
    }

    #[test]
    fn degenerate_cases() {
        let empty = HuffmanCode::from_freqs(&[0, 0, 0]);
        assert_eq!(empty.coded_bits(&[0, 0, 0]), 0);
        let single = HuffmanCode::from_freqs(&[0, 42, 0]);
        assert_eq!(single.lengths[1], 1);
        assert_eq!(single.coded_bits(&[0, 42, 0]), 42);
    }

    #[test]
    fn deterministic() {
        let freqs = [5u64, 5, 5, 5, 5];
        let a = HuffmanCode::from_freqs(&freqs);
        let b = HuffmanCode::from_freqs(&freqs);
        assert_eq!(a.lengths, b.lengths);
    }
}
