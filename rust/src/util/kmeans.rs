//! 1-D k-means (Lloyd's algorithm) for weight clustering — the "trained
//! quantization" stage of Deep Compression (Han et al. 2015).

/// Cluster `values` into `k` centroids.  Returns (centroids, assignment).
/// Deterministic: centroids initialize at evenly-spaced quantiles.
pub fn kmeans_1d(values: &[f32], k: usize, iters: usize) -> (Vec<f32>, Vec<u32>) {
    assert!(k >= 1);
    if values.is_empty() {
        return (vec![0.0; k], Vec::new());
    }
    let mut sorted = values.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mut centroids: Vec<f32> = (0..k)
        .map(|i| {
            let pos = (i as f64 + 0.5) / k as f64 * (sorted.len() - 1) as f64;
            sorted[pos.round() as usize]
        })
        .collect();
    // Deduplicate identical initial centroids by nudging.
    for i in 1..k {
        if centroids[i] <= centroids[i - 1] {
            centroids[i] = centroids[i - 1] + 1e-7;
        }
    }

    let mut assign = vec![0u32; values.len()];
    for _ in 0..iters {
        // Assignment: nearest centroid (centroids stay sorted; binary
        // search would be O(log k) but k <= 256 so linear is fine).
        for (i, &v) in values.iter().enumerate() {
            let mut best = 0usize;
            let mut bd = f32::INFINITY;
            for (c, &cv) in centroids.iter().enumerate() {
                let d = (v - cv).abs();
                if d < bd {
                    bd = d;
                    best = c;
                }
            }
            assign[i] = best as u32;
        }
        // Update.
        let mut sums = vec![0f64; k];
        let mut counts = vec![0u64; k];
        for (&a, &v) in assign.iter().zip(values) {
            sums[a as usize] += v as f64;
            counts[a as usize] += 1;
        }
        let mut moved = 0.0f32;
        for c in 0..k {
            if counts[c] > 0 {
                let nc = (sums[c] / counts[c] as f64) as f32;
                moved += (nc - centroids[c]).abs();
                centroids[c] = nc;
            }
        }
        if moved < 1e-7 {
            break;
        }
    }
    (centroids, assign)
}

/// Replace each value with its centroid; returns cluster frequencies too.
pub fn quantize_to_clusters(values: &[f32], k: usize, iters: usize) -> (Vec<f32>, Vec<u64>, Vec<f32>) {
    let (centroids, assign) = kmeans_1d(values, k, iters);
    let mut freqs = vec![0u64; k];
    let out = assign
        .iter()
        .map(|&a| {
            freqs[a as usize] += 1;
            centroids[a as usize]
        })
        .collect();
    (out, freqs, centroids)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn separates_two_clear_clusters() {
        let mut v = vec![];
        for i in 0..50 {
            v.push(1.0 + (i as f32) * 1e-3);
            v.push(5.0 + (i as f32) * 1e-3);
        }
        let (c, assign) = kmeans_1d(&v, 2, 20);
        let mut cs = c.clone();
        cs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert!((cs[0] - 1.025).abs() < 0.05, "{cs:?}");
        assert!((cs[1] - 5.025).abs() < 0.05, "{cs:?}");
        // Same-cluster values agree.
        assert_eq!(assign[0], assign[2]);
        assert_ne!(assign[0], assign[1]);
    }

    #[test]
    fn at_most_k_distinct_values() {
        let mut rng = Rng::new(1);
        let v: Vec<f32> = (0..5000).map(|_| rng.normal()).collect();
        for k in [2, 4, 16] {
            let (q, freqs, _) = quantize_to_clusters(&v, k, 15);
            let mut uniq = q.clone();
            uniq.sort_by(|a, b| a.partial_cmp(b).unwrap());
            uniq.dedup();
            assert!(uniq.len() <= k);
            assert_eq!(freqs.iter().sum::<u64>(), v.len() as u64);
        }
    }

    #[test]
    fn error_decreases_with_k() {
        let mut rng = Rng::new(2);
        let v: Vec<f32> = (0..2000).map(|_| rng.normal()).collect();
        let err = |k: usize| {
            let (q, _, _) = quantize_to_clusters(&v, k, 20);
            v.iter().zip(&q).map(|(a, b)| (a - b) * (a - b)).sum::<f32>()
        };
        let (e2, e8, e32) = (err(2), err(8), err(32));
        assert!(e2 > e8 && e8 > e32, "{e2} {e8} {e32}");
    }

    #[test]
    fn deterministic() {
        let v: Vec<f32> = (0..100).map(|i| (i as f32 * 0.7).sin()).collect();
        assert_eq!(kmeans_1d(&v, 8, 10), kmeans_1d(&v, 8, 10));
    }

    #[test]
    fn k_one_collapses_to_mean() {
        let v = [1.0f32, 2.0, 3.0];
        let (c, a) = kmeans_1d(&v, 1, 5);
        assert!((c[0] - 2.0).abs() < 1e-6);
        assert_eq!(a, vec![0, 0, 0]);
    }
}
