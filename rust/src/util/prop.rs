//! Minimal property-testing harness (proptest is unavailable offline).
//!
//! `check(name, cases, gen, prop)` draws `cases` random inputs from `gen`,
//! asserts `prop` on each, and on failure performs greedy shrinking via the
//! input's `Shrink` implementation before panicking with the minimal
//! counter-example.  Deterministic: the seed is fixed per property name so
//! CI failures replay.

use super::rng::Rng;

/// Types that can propose smaller versions of themselves.
pub trait Shrink: Sized + Clone + std::fmt::Debug {
    /// Candidate strictly-smaller values, roughly ordered most-aggressive
    /// first.  Default: no shrinking.
    fn shrink(&self) -> Vec<Self> {
        Vec::new()
    }
}

impl Shrink for usize {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self > 0 {
            out.push(0);
            out.push(self / 2);
            out.push(self - 1);
        }
        out.dedup();
        out
    }
}

impl Shrink for f32 {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self != 0.0 {
            out.push(0.0);
            out.push(self / 2.0);
        }
        out
    }
}

impl Shrink for f64 {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self != 0.0 {
            out.push(0.0);
            out.push(self / 2.0);
        }
        out
    }
}

impl Shrink for u64 {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self > 0 {
            out.push(0);
            out.push(self / 2);
        }
        out
    }
}

impl<T: Shrink> Shrink for Vec<T> {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if !self.is_empty() {
            out.push(self[..self.len() / 2].to_vec());
            let mut tail = self.clone();
            tail.remove(0);
            out.push(tail);
            // Element-wise shrink of the first element.
            if let Some(smaller) = self[0].shrink().into_iter().next() {
                let mut v = self.clone();
                v[0] = smaller;
                out.push(v);
            }
        }
        out
    }
}

impl<A: Shrink, B: Shrink> Shrink for (A, B) {
    fn shrink(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self.0.shrink().into_iter().map(|a| (a, self.1.clone())).collect();
        out.extend(self.1.shrink().into_iter().map(|b| (self.0.clone(), b)));
        out
    }
}

impl<A: Shrink, B: Shrink, C: Shrink> Shrink for (A, B, C) {
    fn shrink(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self
            .0
            .shrink()
            .into_iter()
            .map(|a| (a, self.1.clone(), self.2.clone()))
            .collect();
        out.extend(self.1.shrink().into_iter().map(|b| (self.0.clone(), b, self.2.clone())));
        out.extend(self.2.shrink().into_iter().map(|c| (self.0.clone(), self.1.clone(), c)));
        out
    }
}

fn seed_from_name(name: &str) -> u64 {
    // FNV-1a.
    let mut h: u64 = 0xcbf29ce484222325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Run a property over `cases` random inputs; shrink on failure.
pub fn check<T, G, P>(name: &str, cases: usize, mut gen: G, prop: P)
where
    T: Shrink,
    G: FnMut(&mut Rng) -> T,
    P: Fn(&T) -> Result<(), String>,
{
    let mut rng = Rng::new(seed_from_name(name));
    for case in 0..cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            // Greedy shrink: repeatedly take the first failing candidate.
            let mut cur = input;
            let mut cur_msg = msg;
            let mut budget = 200;
            'shrinking: while budget > 0 {
                budget -= 1;
                for cand in cur.shrink() {
                    if let Err(m) = prop(&cand) {
                        cur = cand;
                        cur_msg = m;
                        continue 'shrinking;
                    }
                }
                break;
            }
            panic!(
                "property `{name}` failed (case {case}):\n  input: {cur:?}\n  error: {cur_msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check("add commutes", 100, |r| (r.below(1000), r.below(1000)), |&(a, b)| {
            if a + b == b + a {
                Ok(())
            } else {
                Err("math broke".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property `always fails`")]
    fn failing_property_panics() {
        check("always fails", 10, |r| r.below(100), |_| Err("nope".into()));
    }

    #[test]
    fn shrinks_to_small_counterexample() {
        let got = std::panic::catch_unwind(|| {
            check("fails above 10", 200, |r| r.below(1000), |&x| {
                if x <= 10 {
                    Ok(())
                } else {
                    Err(format!("{x} > 10"))
                }
            });
        });
        let msg = *got.unwrap_err().downcast::<String>().unwrap();
        // Greedy shrink must land at 11 (minimal failing value).
        assert!(msg.contains("input: 11"), "{msg}");
    }
}
