//! Poison-recovering lock/condvar helpers.
//!
//! `std`'s `Mutex` poisons when a holder panics; with fault injection (and
//! `catch_unwind` worker isolation) a panic near a lock is a *routine*
//! event, and `.lock().unwrap()` would cascade one injected panic into a
//! panic in every thread that touches the lock afterwards.  All the data
//! these locks guard is valid at every instruction boundary (queues push
//! or pop whole elements; counters are plain integers), so recovery is
//! simply taking the guard — the idiom `chain::plan` and `obs` already
//! use, centralized here for the serve subsystem and everything else.

use std::sync::{Condvar, Mutex, MutexGuard};
use std::time::Duration;

/// Lock, recovering from poison (see module docs for why this is sound).
pub fn lock<'a, T>(m: &'a Mutex<T>) -> MutexGuard<'a, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Condvar wait, recovering from poison.
pub fn wait<'a, T>(cv: &Condvar, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(guard).unwrap_or_else(|e| e.into_inner())
}

/// Condvar wait with timeout, recovering from poison.  Returns the guard
/// and whether the wait timed out.
pub fn wait_timeout<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
    dur: Duration,
) -> (MutexGuard<'a, T>, bool) {
    match cv.wait_timeout(guard, dur) {
        Ok((g, r)) => (g, r.timed_out()),
        Err(e) => {
            let (g, r) = e.into_inner();
            (g, r.timed_out())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn lock_recovers_from_poison() {
        let m = Arc::new(Mutex::new(7u32));
        let mc = m.clone();
        let _ = std::thread::spawn(move || {
            let _g = mc.lock().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(m.lock().is_err(), "mutex should be poisoned");
        assert_eq!(*lock(&m), 7, "helper must recover the guarded value");
        *lock(&m) = 9;
        assert_eq!(*lock(&m), 9);
    }

    #[test]
    fn wait_timeout_reports_timeout() {
        let m = Mutex::new(());
        let cv = Condvar::new();
        let g = lock(&m);
        let (_g, timed_out) = wait_timeout(&cv, g, Duration::from_millis(1));
        assert!(timed_out);
    }
}
