//! Small statistics helpers shared by metrics, benches and the serve loop.

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Sample standard deviation (0 for n < 2).
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Percentile by linear interpolation, q clamped to [0, 100].
///
/// Total on all inputs: empty slices return 0.0 (never index), singleton
/// slices return their one element for every q, and out-of-range q values
/// clamp rather than walking off the sorted vector.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = (q.clamp(0.0, 100.0) / 100.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = (pos.ceil() as usize).min(v.len() - 1);
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (pos - lo as f64)
    }
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Running summary for streaming latency measurements.
///
/// Two representations behind one API:
///
/// * **Exact** (the default): every sample is retained and percentiles
///   are computed by sort + interpolation — bit-for-bit the historical
///   behavior, still right for bounded runs and for tests that assert
///   exact quantiles.
/// * **Bounded** ([`Summary::bounded`]): O(1) memory regardless of sample
///   count — a fixed log2-bucket histogram (`obs::metrics::Histogram`)
///   plus exact count/sum/min/max.  Percentiles interpolate within the
///   owning bucket and clamp to the observed [min, max].  This is what
///   the open-loop load generator records into: an hours-long soak at
///   thousands of requests/sec previously grew a `Vec<f64>` without
///   bound.
///
/// Merging promotes: exact+exact stays exact; anything involving a
/// bounded side becomes bounded (bucket-wise adds — associative and
/// deterministic).
#[derive(Debug, Clone)]
pub struct Summary {
    repr: Repr,
}

#[derive(Debug, Clone)]
enum Repr {
    Exact(Vec<f64>),
    Bounded { hist: crate::obs::metrics::Histogram, count: u64, sum: f64, min: f64, max: f64 },
}

impl Default for Summary {
    fn default() -> Self {
        Summary { repr: Repr::Exact(Vec::new()) }
    }
}

impl Summary {
    /// Fixed-memory summary backed by the log2-bucket histogram.
    pub fn bounded() -> Summary {
        Summary {
            repr: Repr::Bounded {
                hist: crate::obs::metrics::Histogram::default(),
                count: 0,
                sum: 0.0,
                min: f64::INFINITY,
                max: f64::NEG_INFINITY,
            },
        }
    }

    pub fn is_bounded(&self) -> bool {
        matches!(self.repr, Repr::Bounded { .. })
    }

    pub fn push(&mut self, x: f64) {
        match &mut self.repr {
            Repr::Exact(v) => v.push(x),
            Repr::Bounded { hist, count, sum, min, max } => {
                hist.record(x);
                *count += 1;
                *sum += x;
                *min = min.min(x);
                *max = max.max(x);
            }
        }
    }

    /// Fold another summary into this one.  Exact+exact concatenates;
    /// any bounded operand promotes the result to bounded.
    pub fn merge(&mut self, other: &Summary) {
        match &other.repr {
            Repr::Exact(b) => match &mut self.repr {
                Repr::Exact(a) => a.extend_from_slice(b),
                Repr::Bounded { .. } => {
                    for &x in b {
                        self.push(x);
                    }
                }
            },
            Repr::Bounded { hist, count, sum, min, max } => {
                if *count == 0 {
                    return;
                }
                self.promote_to_bounded();
                if let Repr::Bounded { hist: h, count: c, sum: s, min: mn, max: mx } =
                    &mut self.repr
                {
                    h.merge(hist);
                    *c += *count;
                    *s += *sum;
                    *mn = mn.min(*min);
                    *mx = mx.max(*max);
                }
            }
        }
    }

    fn promote_to_bounded(&mut self) {
        if let Repr::Exact(v) = &self.repr {
            let mut b = Summary::bounded();
            for &x in v {
                b.push(x);
            }
            *self = b;
        }
    }

    /// Retained samples — exact mode only; a bounded summary returns the
    /// empty slice (it keeps buckets, not samples).  Use [`Summary::count_le`]
    /// for threshold counts that work in both modes.
    pub fn samples(&self) -> &[f64] {
        match &self.repr {
            Repr::Exact(v) => v,
            Repr::Bounded { .. } => &[],
        }
    }

    /// How many recorded values are `<= x` — exact in exact mode, bucket
    /// resolution in bounded mode (exact at and beyond the observed
    /// extremes).
    pub fn count_le(&self, x: f64) -> usize {
        match &self.repr {
            Repr::Exact(v) => v.iter().filter(|&&l| l <= x).count(),
            Repr::Bounded { hist, count, min, max, .. } => {
                if *count == 0 || x < *min {
                    0
                } else if x >= *max {
                    *count as usize
                } else {
                    (hist.count_le(x) as usize).min(*count as usize)
                }
            }
        }
    }

    pub fn len(&self) -> usize {
        match &self.repr {
            Repr::Exact(v) => v.len(),
            Repr::Bounded { count, .. } => *count as usize,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn mean(&self) -> f64 {
        match &self.repr {
            Repr::Exact(v) => mean(v),
            Repr::Bounded { count, sum, .. } => {
                if *count == 0 {
                    0.0
                } else {
                    sum / *count as f64
                }
            }
        }
    }

    pub fn percentile(&self, q: f64) -> f64 {
        match &self.repr {
            Repr::Exact(v) => percentile(v, q),
            Repr::Bounded { hist, count, min, max, .. } => {
                if *count == 0 {
                    0.0
                } else {
                    hist.quantile(q).clamp(*min, *max)
                }
            }
        }
    }

    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p95(&self) -> f64 {
        self.percentile(95.0)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }

    /// 0.0 for an empty summary (not +inf — callers print these raw).
    pub fn min(&self) -> f64 {
        if self.is_empty() {
            return 0.0;
        }
        match &self.repr {
            Repr::Exact(v) => v.iter().cloned().fold(f64::INFINITY, f64::min),
            Repr::Bounded { min, .. } => *min,
        }
    }

    /// 0.0 for an empty summary (not -inf — callers print these raw).
    pub fn max(&self) -> f64 {
        if self.is_empty() {
            return 0.0;
        }
        match &self.repr {
            Repr::Exact(v) => v.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
            Repr::Bounded { max, .. } => *max,
        }
    }
}

/// Pareto frontier of (x=compression ratio, y=accuracy) points:
/// a point survives if no other point has both >= x and >= y (strictly
/// better in at least one).  Returned sorted by x ascending.
pub fn pareto_frontier(points: &[(f64, f64)]) -> Vec<(f64, f64)> {
    let mut keep = Vec::new();
    'outer: for (i, &(x, y)) in points.iter().enumerate() {
        for (j, &(x2, y2)) in points.iter().enumerate() {
            if i != j && x2 >= x && y2 >= y && (x2 > x || y2 > y) {
                continue 'outer;
            }
        }
        keep.push((x, y));
    }
    keep.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    keep.dedup();
    keep
}

/// Area-under-frontier proxy: mean accuracy of the frontier weighted by
/// log-compression span — a scalar "who wins" score used to compare two
/// orderings of the same pair (higher is better).
pub fn frontier_score(points: &[(f64, f64)]) -> f64 {
    let f = pareto_frontier(points);
    if f.len() < 2 {
        return f.first().map(|p| p.1).unwrap_or(0.0);
    }
    let mut area = 0.0;
    let mut span = 0.0;
    for w in f.windows(2) {
        let dx = (w[1].0.ln() - w[0].0.ln()).max(0.0);
        area += dx * 0.5 * (w[0].1 + w[1].1);
        span += dx;
    }
    if span == 0.0 {
        f[0].1
    } else {
        area / span
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((stddev(&xs) - 1.2909944).abs() < 1e-5);
    }

    #[test]
    fn percentiles() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(median(&xs), 2.5);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
    }

    #[test]
    fn pareto_drops_dominated() {
        let pts = [(1.0, 0.9), (2.0, 0.8), (1.5, 0.7), (3.0, 0.5)];
        let f = pareto_frontier(&pts);
        assert_eq!(f, vec![(1.0, 0.9), (2.0, 0.8), (3.0, 0.5)]);
    }

    #[test]
    fn frontier_score_orders_dominance() {
        // Frontier B dominates A everywhere -> higher score.
        let a = [(10.0, 0.80), (100.0, 0.60)];
        let b = [(10.0, 0.90), (100.0, 0.85)];
        assert!(frontier_score(&b) > frontier_score(&a));
    }

    #[test]
    fn empty_summary_is_all_zeros() {
        let s = Summary::default();
        assert!(s.is_empty());
        assert_eq!(s.len(), 0);
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.p50(), 0.0);
        assert_eq!(s.p95(), 0.0);
        assert_eq!(s.p99(), 0.0);
        assert_eq!(s.min(), 0.0);
        assert_eq!(s.max(), 0.0);
        // The free functions are total on empty input too.
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(median(&[]), 0.0);
    }

    #[test]
    fn singleton_summary_returns_its_element() {
        let mut s = Summary::default();
        s.push(7.5);
        assert_eq!(s.len(), 1);
        for q in [0.0, 50.0, 95.0, 99.0, 100.0] {
            assert_eq!(s.percentile(q), 7.5, "q={q}");
        }
        assert_eq!(s.min(), 7.5);
        assert_eq!(s.max(), 7.5);
        assert_eq!(s.mean(), 7.5);
        // Out-of-range q clamps instead of indexing out of bounds.
        assert_eq!(percentile(&[7.5], 150.0), 7.5);
        assert_eq!(percentile(&[7.5], -5.0), 7.5);
    }

    #[test]
    fn summary_merge_combines_samples() {
        let mut a = Summary::default();
        let mut b = Summary::default();
        for i in 0..50 {
            a.push(i as f64);
        }
        for i in 50..100 {
            b.push(i as f64);
        }
        a.merge(&b);
        assert_eq!(a.len(), 100);
        assert_eq!(a.min(), 0.0);
        assert_eq!(a.max(), 99.0);
        assert!((a.p50() - 49.5).abs() < 1.0);
        // Merging an empty summary is a no-op.
        a.merge(&Summary::default());
        assert_eq!(a.len(), 100);
    }

    #[test]
    fn summary_quantiles() {
        let mut s = Summary::default();
        for i in 1..=100 {
            s.push(i as f64);
        }
        assert_eq!(s.len(), 100);
        assert!((s.p50() - 50.5).abs() < 1.0);
        assert!(s.p99() > 98.0);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 100.0);
    }

    #[test]
    fn bounded_summary_tracks_exact_within_bucket_resolution() {
        let mut exact = Summary::default();
        let mut bounded = Summary::bounded();
        assert!(bounded.is_bounded());
        assert!(!exact.is_bounded());
        for i in 1..=1000 {
            let v = (i as f64) * 17.0; // latencies 17..17000 "µs"
            exact.push(v);
            bounded.push(v);
        }
        assert_eq!(bounded.len(), exact.len());
        assert_eq!(bounded.min(), exact.min());
        assert_eq!(bounded.max(), exact.max());
        assert!((bounded.mean() - exact.mean()).abs() < 1e-9);
        // Log2 buckets: estimates within 2x of the exact quantile.
        for q in [10.0, 50.0, 95.0, 99.0] {
            let (e, b) = (exact.percentile(q), bounded.percentile(q));
            assert!(b >= e / 2.0 && b <= e * 2.0, "q={q}: exact {e} bounded {b}");
        }
        // Quantiles stay monotone in q (serve tests assert p50<=p95<=p99).
        assert!(bounded.p50() <= bounded.p95());
        assert!(bounded.p95() <= bounded.p99());
        // count_le is exact at and beyond the extremes.
        assert_eq!(bounded.count_le(16.9), 0);
        assert_eq!(bounded.count_le(17_000.0), 1000);
        // ...and within 2x bucket slack in the interior.
        let exact_mid = exact.count_le(8500.0) as f64;
        let bounded_mid = bounded.count_le(8500.0) as f64;
        assert!(bounded_mid >= exact_mid / 2.0 && bounded_mid <= exact_mid * 2.0);
    }

    #[test]
    fn bounded_singleton_is_exact() {
        let mut s = Summary::bounded();
        s.push(7.5);
        // One sample: quantiles clamp into [min, max] = [7.5, 7.5].
        for q in [0.0, 50.0, 99.0, 100.0] {
            assert_eq!(s.percentile(q), 7.5, "q={q}");
        }
        assert_eq!(s.count_le(7.5), 1);
        assert_eq!(s.count_le(7.4), 0);
    }

    #[test]
    fn merge_promotes_exact_into_bounded() {
        let mut exact = Summary::default();
        for i in 0..50 {
            exact.push(i as f64 + 1.0);
        }
        let mut bounded = Summary::bounded();
        for i in 50..100 {
            bounded.push(i as f64 + 1.0);
        }
        // exact += bounded -> result is bounded and covers the union.
        let mut merged = exact.clone();
        merged.merge(&bounded);
        assert!(merged.is_bounded());
        assert_eq!(merged.len(), 100);
        assert_eq!(merged.min(), 1.0);
        assert_eq!(merged.max(), 100.0);
        // bounded += exact also works, and agrees with the other order.
        let mut merged2 = bounded.clone();
        merged2.merge(&exact);
        assert_eq!(merged2.len(), 100);
        assert_eq!(merged2.p95(), merged.p95());
        // Merging an empty bounded summary does not promote an exact one.
        let mut still_exact = Summary::default();
        still_exact.push(3.0);
        still_exact.merge(&Summary::bounded());
        assert!(!still_exact.is_bounded());
        assert_eq!(still_exact.samples(), &[3.0]);
    }

    #[test]
    fn bounded_summary_has_fixed_footprint() {
        // The whole point: no per-sample allocation.  We can't measure RSS
        // in a unit test, but we can pin the API contract that no samples
        // are retained.
        let mut s = Summary::bounded();
        for i in 0..100_000 {
            s.push((i % 997) as f64 + 1.0);
        }
        assert_eq!(s.len(), 100_000);
        assert!(s.samples().is_empty());
    }
}
