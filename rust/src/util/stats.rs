//! Small statistics helpers shared by metrics, benches and the serve loop.

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Sample standard deviation (0 for n < 2).
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Percentile by linear interpolation, q clamped to [0, 100].
///
/// Total on all inputs: empty slices return 0.0 (never index), singleton
/// slices return their one element for every q, and out-of-range q values
/// clamp rather than walking off the sorted vector.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = (q.clamp(0.0, 100.0) / 100.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = (pos.ceil() as usize).min(v.len() - 1);
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (pos - lo as f64)
    }
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Running summary for streaming latency measurements.
#[derive(Debug, Default, Clone)]
pub struct Summary {
    samples: Vec<f64>,
}

impl Summary {
    pub fn push(&mut self, x: f64) {
        self.samples.push(x);
    }

    /// Fold another summary's samples into this one.
    pub fn merge(&mut self, other: &Summary) {
        self.samples.extend_from_slice(&other.samples);
    }

    pub fn samples(&self) -> &[f64] {
        &self.samples
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn mean(&self) -> f64 {
        mean(&self.samples)
    }

    pub fn percentile(&self, q: f64) -> f64 {
        percentile(&self.samples, q)
    }

    pub fn p50(&self) -> f64 {
        percentile(&self.samples, 50.0)
    }

    pub fn p95(&self) -> f64 {
        percentile(&self.samples, 95.0)
    }

    pub fn p99(&self) -> f64 {
        percentile(&self.samples, 99.0)
    }

    /// 0.0 for an empty summary (not +inf — callers print these raw).
    pub fn min(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    /// 0.0 for an empty summary (not -inf — callers print these raw).
    pub fn max(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }
}

/// Pareto frontier of (x=compression ratio, y=accuracy) points:
/// a point survives if no other point has both >= x and >= y (strictly
/// better in at least one).  Returned sorted by x ascending.
pub fn pareto_frontier(points: &[(f64, f64)]) -> Vec<(f64, f64)> {
    let mut keep = Vec::new();
    'outer: for (i, &(x, y)) in points.iter().enumerate() {
        for (j, &(x2, y2)) in points.iter().enumerate() {
            if i != j && x2 >= x && y2 >= y && (x2 > x || y2 > y) {
                continue 'outer;
            }
        }
        keep.push((x, y));
    }
    keep.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    keep.dedup();
    keep
}

/// Area-under-frontier proxy: mean accuracy of the frontier weighted by
/// log-compression span — a scalar "who wins" score used to compare two
/// orderings of the same pair (higher is better).
pub fn frontier_score(points: &[(f64, f64)]) -> f64 {
    let f = pareto_frontier(points);
    if f.len() < 2 {
        return f.first().map(|p| p.1).unwrap_or(0.0);
    }
    let mut area = 0.0;
    let mut span = 0.0;
    for w in f.windows(2) {
        let dx = (w[1].0.ln() - w[0].0.ln()).max(0.0);
        area += dx * 0.5 * (w[0].1 + w[1].1);
        span += dx;
    }
    if span == 0.0 {
        f[0].1
    } else {
        area / span
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((stddev(&xs) - 1.2909944).abs() < 1e-5);
    }

    #[test]
    fn percentiles() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(median(&xs), 2.5);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
    }

    #[test]
    fn pareto_drops_dominated() {
        let pts = [(1.0, 0.9), (2.0, 0.8), (1.5, 0.7), (3.0, 0.5)];
        let f = pareto_frontier(&pts);
        assert_eq!(f, vec![(1.0, 0.9), (2.0, 0.8), (3.0, 0.5)]);
    }

    #[test]
    fn frontier_score_orders_dominance() {
        // Frontier B dominates A everywhere -> higher score.
        let a = [(10.0, 0.80), (100.0, 0.60)];
        let b = [(10.0, 0.90), (100.0, 0.85)];
        assert!(frontier_score(&b) > frontier_score(&a));
    }

    #[test]
    fn empty_summary_is_all_zeros() {
        let s = Summary::default();
        assert!(s.is_empty());
        assert_eq!(s.len(), 0);
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.p50(), 0.0);
        assert_eq!(s.p95(), 0.0);
        assert_eq!(s.p99(), 0.0);
        assert_eq!(s.min(), 0.0);
        assert_eq!(s.max(), 0.0);
        // The free functions are total on empty input too.
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(median(&[]), 0.0);
    }

    #[test]
    fn singleton_summary_returns_its_element() {
        let mut s = Summary::default();
        s.push(7.5);
        assert_eq!(s.len(), 1);
        for q in [0.0, 50.0, 95.0, 99.0, 100.0] {
            assert_eq!(s.percentile(q), 7.5, "q={q}");
        }
        assert_eq!(s.min(), 7.5);
        assert_eq!(s.max(), 7.5);
        assert_eq!(s.mean(), 7.5);
        // Out-of-range q clamps instead of indexing out of bounds.
        assert_eq!(percentile(&[7.5], 150.0), 7.5);
        assert_eq!(percentile(&[7.5], -5.0), 7.5);
    }

    #[test]
    fn summary_merge_combines_samples() {
        let mut a = Summary::default();
        let mut b = Summary::default();
        for i in 0..50 {
            a.push(i as f64);
        }
        for i in 50..100 {
            b.push(i as f64);
        }
        a.merge(&b);
        assert_eq!(a.len(), 100);
        assert_eq!(a.min(), 0.0);
        assert_eq!(a.max(), 99.0);
        assert!((a.p50() - 49.5).abs() < 1.0);
        // Merging an empty summary is a no-op.
        a.merge(&Summary::default());
        assert_eq!(a.len(), 100);
    }

    #[test]
    fn summary_quantiles() {
        let mut s = Summary::default();
        for i in 1..=100 {
            s.push(i as f64);
        }
        assert_eq!(s.len(), 100);
        assert!((s.p50() - 50.5).abs() < 1.0);
        assert!(s.p99() > 98.0);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 100.0);
    }
}
