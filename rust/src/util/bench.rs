//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! `cargo bench` runs `rust/benches/bench_main.rs` with `harness = false`;
//! that binary uses this module.  Methodology: warmup runs, then timed
//! iterations until both a minimum iteration count and a minimum wall time
//! are reached; reports mean / p50 / p95 and a throughput line.

use std::time::{Duration, Instant};

use super::stats;

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
}

impl BenchResult {
    pub fn report(&self) {
        println!(
            "bench {:<44} {:>10} iters  mean {:>12}  p50 {:>12}  p95 {:>12}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p95_ns),
        );
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

pub struct Bencher {
    /// Minimum number of timed iterations.
    pub min_iters: usize,
    /// Minimum total measurement time.
    pub min_time: Duration,
    /// Warmup iterations before measurement.
    pub warmup_iters: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { min_iters: 10, min_time: Duration::from_millis(300), warmup_iters: 2 }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher { min_iters: 3, min_time: Duration::from_millis(50), warmup_iters: 1 }
    }

    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples = Vec::new();
        let start = Instant::now();
        while samples.len() < self.min_iters || start.elapsed() < self.min_time {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_nanos() as f64);
            if samples.len() > 10_000 {
                break;
            }
        }
        let res = BenchResult {
            name: name.to_string(),
            iters: samples.len(),
            mean_ns: stats::mean(&samples),
            p50_ns: stats::percentile(&samples, 50.0),
            p95_ns: stats::percentile(&samples, 95.0),
        };
        res.report();
        res
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let b = Bencher::quick();
        let mut acc = 0u64;
        let r = b.run("noop-ish", || {
            acc = acc.wrapping_add(std::hint::black_box(1));
        });
        assert!(r.iters >= 3);
        assert!(r.mean_ns >= 0.0);
    }

    #[test]
    fn formats_units() {
        assert!(fmt_ns(500.0).ends_with("ns"));
        assert!(fmt_ns(5_000.0).ends_with("µs"));
        assert!(fmt_ns(5_000_000.0).ends_with("ms"));
        assert!(fmt_ns(5e9).ends_with("s"));
    }
}
