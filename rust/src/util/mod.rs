//! In-repo substrates.
//!
//! The build environment is fully offline and its crate set is exactly the
//! `xla` crate's dependency closure, so the usual ecosystem crates (serde,
//! clap, rand, criterion, proptest, rayon) are unavailable.  Everything the
//! coordinator needs beyond `xla`/`anyhow`/`thiserror` is implemented here
//! from scratch (see DESIGN.md "Substrates built from scratch").

pub mod bench;
pub mod cli;
pub mod huffman;
pub mod json;
pub mod kmeans;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod sync;
