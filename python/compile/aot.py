"""AOT compile path: lower every graph to HLO *text* + write manifest.json.

HLO text (NOT ``lowered.compile()`` / serialized protos) is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which
xla_extension 0.5.1 (the version behind the published ``xla`` rust crate)
rejects; the text parser reassigns ids and round-trips cleanly.  See
/opt/xla-example/README.md.

Usage:  cd python && python -m compile.aot --out ../artifacts
Python runs ONCE here; the rust binary is self-contained afterwards.
"""

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import archs, model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _write(out_dir, fname, text):
    path = os.path.join(out_dir, fname)
    with open(path, "w") as f:
        f.write(text)
    return fname, hashlib.sha256(text.encode()).hexdigest()[:16], len(text)


def lower_arch(net, out_dir, stage_batches=model.STAGE_BATCHES):
    """Lower all graphs for one architecture; return manifest entries."""
    f32 = jnp.float32
    P = model.param_specs(net)
    M = model.mask_specs(net)
    S = model.scalar()
    nclass = archs.NUM_CLASSES
    img = jax.ShapeDtypeStruct(
        (model.TRAIN_BATCH, archs.IMG_HW, archs.IMG_HW, archs.IMG_C), f32)
    img_eval = jax.ShapeDtypeStruct(
        (model.EVAL_BATCH, archs.IMG_HW, archs.IMG_HW, archs.IMG_C), f32)
    y1h = jax.ShapeDtypeStruct((model.TRAIN_BATCH, nclass), f32)
    tlog = jax.ShapeDtypeStruct((model.TRAIN_BATCH, nclass), f32)
    exit_w = jax.ShapeDtypeStruct((2,), f32)
    hp = jax.ShapeDtypeStruct((3,), f32)
    stage_batches = sorted(set(int(b) for b in stage_batches) | {1})

    graphs = {}

    def lower(tag, fn, *specs):
        # keep_unused: stage graphs consume only a subset of params; without
        # this, XLA prunes unused operands from the signature and the rust
        # side (which passes the full flat param list) trips a buffer-count
        # mismatch at execute time.
        low = jax.jit(fn, keep_unused=True).lower(*specs)
        fname, sha, size = _write(out_dir, f"{net.name}_{tag}.hlo.txt",
                                  to_hlo_text(low))
        graphs[tag] = {"file": fname, "sha256": sha, "bytes": size}

    # init: seed -> params ++ momenta
    lower("init", model.make_init_fn(net), S)

    # train: flat operand list (params*, momenta*, x, y, masks*, qbw, qba,
    #         tlogits, kd_alpha, kd_tau, exit_w, hp)
    train_step = model.make_train_step(net)
    nP = len(P)

    def train_flat(*ops):
        i = 0
        params = list(ops[i:i + nP]); i += nP
        momenta = list(ops[i:i + nP]); i += nP
        x = ops[i]; i += 1
        y = ops[i]; i += 1
        masks = list(ops[i:i + len(M)]); i += len(M)
        qbw = ops[i]; i += 1
        qba = ops[i]; i += 1
        tl = ops[i]; i += 1
        ka = ops[i]; i += 1
        kt = ops[i]; i += 1
        ew = ops[i]; i += 1
        hps = ops[i]; i += 1
        return train_step(params, momenta, x, y, masks, qbw, qba, tl, ka, kt, ew, hps)

    lower("train", train_flat,
          *P, *P, img, y1h, *M, S, S, tlog, S, S, exit_w, hp)

    # eval: (params*, masks*, qbw, qba, x) -> (logits, e1, e2)
    eval_step = model.make_eval_step(net)

    def eval_flat(*ops):
        params = list(ops[:nP])
        masks = list(ops[nP:nP + len(M)])
        qbw, qba, x = ops[nP + len(M):]
        return eval_step(params, masks, x, qbw, qba)

    lower("eval", eval_flat, *P, *M, S, S, img_eval)

    # staged eval (serving path: genuinely skip later segments), lowered at
    # every serving batch size: batch 1 is the single-stream contract, the
    # larger sizes are what the rust micro-batcher pads request groups to.
    s1, s2, s3 = model.make_stage_fns(net)

    def stage_flat(fn):
        def f(*ops):
            params = list(ops[:nP])
            masks = list(ops[nP:nP + len(M)])
            qbw, qba, x = ops[nP + len(M):]
            return fn(params, masks, x, qbw, qba)
        return f

    f1 = stage_flat(lambda p, m, x, bw, ba: s1(p, m, x, bw, ba))
    f2 = stage_flat(lambda p, m, h, bw, ba: s2(p, m, h, bw, ba))
    f3 = stage_flat(lambda p, m, h, bw, ba: s3(p, m, h, bw, ba))

    for sb in stage_batches:
        suffix = "" if sb == 1 else f"_b{sb}"
        img_sb = jax.ShapeDtypeStruct(
            (sb, archs.IMG_HW, archs.IMG_HW, archs.IMG_C), f32)
        h1_sb, h2_sb = model.seg_out_shape(net, sb)
        lower(f"stage1{suffix}", f1, *P, *M, S, S, img_sb)
        lower(f"stage2{suffix}", f2, *P, *M, S, S,
              jax.ShapeDtypeStruct(h1_sb, f32))
        lower(f"stage3{suffix}", f3, *P, *M, S, S,
              jax.ShapeDtypeStruct(h2_sb, f32))

    entry = net.describe()
    h1_eval, h2_eval = model.seg_out_shape(net, model.STAGE_BATCH)
    entry.update({
        "graphs": graphs,
        "train_batch": model.TRAIN_BATCH,
        "eval_batch": model.EVAL_BATCH,
        "stage_batch": model.STAGE_BATCH,
        "stage_batches": sorted(stage_batches),
        "stage_h1_shape": list(h1_eval),
        "stage_h2_shape": list(h2_eval),
        "num_params": len(P),
        "num_masks": len(M),
    })
    return entry


def lower_kernel_bench(out_dir):
    """Standalone qmatmul graphs for the rust-side kernel micro-bench."""
    from .kernels import qmatmul, qmatmul_tiled
    f32 = jnp.float32
    out = {}
    a = jax.ShapeDtypeStruct((128, 256), f32)
    w = jax.ShapeDtypeStruct((256, 128), f32)
    s = model.scalar()
    low = jax.jit(lambda a, w, ba, bw: (qmatmul(a, w, ba, bw),)).lower(a, w, s, s)
    fname, sha, size = _write(out_dir, "kernel_qmatmul.hlo.txt", to_hlo_text(low))
    out["qmatmul"] = {"file": fname, "sha256": sha, "bytes": size,
                      "m": 128, "k": 256, "n": 128}
    for bm, bn, bk, tag in [(64, 64, 128, "t64"), (128, 128, 128, "t128")]:
        low = jax.jit(
            lambda a, w, ba, bw, bm=bm, bn=bn, bk=bk:
            (qmatmul_tiled(a, w, ba, bw, bm=bm, bn=bn, bk=bk),)
        ).lower(a, w, s, s)
        fname, sha, size = _write(out_dir, f"kernel_qmatmul_{tag}.hlo.txt",
                                  to_hlo_text(low))
        out[f"qmatmul_{tag}"] = {"file": fname, "sha256": sha, "bytes": size,
                                 "m": 128, "k": 256, "n": 128,
                                 "bm": bm, "bn": bn, "bk": bk}
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--archs", default="mini_vgg,mini_resnet,mini_mobilenet")
    ap.add_argument("--stage-batches",
                    default=",".join(str(b) for b in model.STAGE_BATCHES),
                    help="comma-separated serving batch sizes to lower the "
                         "staged graphs at (1 is always included)")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    try:
        stage_batches = [int(b) for b in args.stage_batches.split(",") if b]
    except ValueError:
        ap.error(f"--stage-batches expects comma-separated integers, "
                 f"got {args.stage_batches!r}")
    if any(b < 1 for b in stage_batches):
        ap.error(f"--stage-batches entries must be >= 1, got {stage_batches}")
    manifest = {"version": 1, "num_classes": archs.NUM_CLASSES,
                "input": {"h": archs.IMG_HW, "w": archs.IMG_HW, "c": archs.IMG_C},
                "archs": {}, "kernels": {}}
    for name in args.archs.split(","):
        net = archs.build(name)
        print(f"lowering {name} ...", flush=True)
        manifest["archs"][name] = lower_arch(net, args.out, stage_batches)
    print("lowering kernel benches ...", flush=True)
    manifest["kernels"] = lower_kernel_bench(args.out)

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"wrote {args.out}/manifest.json")


if __name__ == "__main__":
    main()
