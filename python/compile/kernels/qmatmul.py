"""L1 Pallas kernel: fused fake-quantized matmul — the QAT hot-spot.

``C = Q_a(A) @ Q_w(W)`` with both operand quantizations fused into the
matmul kernel so quantized values never round-trip through HBM.  Two
variants:

* ``qmatmul`` — single-block kernel with in-kernel per-tensor scales; used
  by the L2 model for classifier / early-exit heads (operands are small
  enough for one VMEM block).
* ``qmatmul_tiled`` — grid-tiled (bm, bk) x (bk, bn) variant with
  precomputed scales passed as scalar operands and an accumulator carried
  across the K grid dimension.  This is the TPU/MXU-shaped path: blocks are
  chosen as multiples of the 128x128 systolic tile, the BlockSpec expresses
  the HBM->VMEM schedule, and quantization happens on the VMEM-resident
  block right before it feeds the MXU.  See DESIGN.md §Hardware-Adaptation
  and §Perf for the footprint/utilization analysis.

Both are lowered with ``interpret=True`` (CPU-PJRT executable HLO).
Backward pass: straight-through through the quantizers, standard matmul
cotangents against the *quantized* operands (recomputed with the pure-jnp
reference — cheap, and keeps the fwd kernel single-purpose).
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _qmatmul_kernel(a_ref, w_ref, ba_ref, bw_ref, o_ref):
    """Single-block fused kernel: in-kernel scales, quantize, matmul."""
    a = a_ref[...]
    w = w_ref[...]
    ba = ba_ref[0, 0]
    bw = bw_ref[0, 0]

    na = jnp.maximum(jnp.exp2(ba) - 1.0, 1.0)
    nw = jnp.maximum(jnp.exp2(bw) - 1.0, 1.0)

    # Activation: dynamic per-tensor scale, clip [0,1], quantize.
    sa = jnp.maximum(jnp.max(jnp.abs(a)), 1e-8)
    an = jnp.clip(a / sa, 0.0, 1.0)
    aq = jnp.round(an * na) / na * sa
    aq = jnp.where(ba > 0, aq, a)

    # Weight: tanh-normalize, quantize, rescale to max|w|.
    t = jnp.tanh(w)
    m = jnp.maximum(jnp.max(jnp.abs(t)), 1e-8)
    tn = t / (2.0 * m) + 0.5
    sw = jnp.maximum(jnp.max(jnp.abs(w)), 1e-8)
    wq = (2.0 * (jnp.round(tn * nw) / nw) - 1.0) * sw
    wq = jnp.where(bw > 0, wq, w)

    o_ref[...] = jnp.dot(aq, wq, preferred_element_type=jnp.float32)


@partial(jax.custom_vjp)
def qmatmul(a, w, bits_a, bits_w):
    """Fused fake-quantized matmul ``(M,K) @ (K,N) -> (M,N)``.

    ``bits_* == 0`` disables the corresponding quantization (fp32 path).
    Backward is straight-through to ``a`` and ``w``.
    """
    ba = jnp.reshape(bits_a.astype(jnp.float32), (1, 1))
    bw = jnp.reshape(bits_w.astype(jnp.float32), (1, 1))
    return pl.pallas_call(
        _qmatmul_kernel,
        out_shape=jax.ShapeDtypeStruct((a.shape[0], w.shape[1]), jnp.float32),
        interpret=True,
    )(a, w, ba, bw)


def _qmatmul_fwd(a, w, bits_a, bits_w):
    out = qmatmul(a, w, bits_a, bits_w)
    return out, (a, w, bits_a, bits_w)


def _qmatmul_bwd(res, g):
    a, w, bits_a, bits_w = res
    # Recompute quantized operands with the jnp reference (cheap at these
    # sizes); cotangents flow straight-through the quantizers.
    aq = ref.act_quant_ref(a, bits_a)
    wq = ref.weight_quant_ref(w, bits_w)
    da = g @ wq.T
    dw = aq.T @ g
    return da, dw, jnp.zeros(()), jnp.zeros(())


qmatmul.defvjp(_qmatmul_fwd, _qmatmul_bwd)


# ---------------------------------------------------------------------------
# Tiled variant (TPU/MXU-shaped; exercised by tests and the kernel bench).
# ---------------------------------------------------------------------------

def _qmatmul_tiled_kernel(a_ref, w_ref, scal_ref, o_ref):
    """Grid-tiled kernel: grid = (M/bm, N/bn, K/bk); K is the innermost
    (minor) grid dimension so the f32 accumulator in ``o_ref`` is carried
    across K steps for a fixed (i, j) output block.

    ``scal_ref`` is a (1, 4) block: [bits_a, bits_w, scale_a, scale_w] —
    per-tensor scales are precomputed by the caller because a block kernel
    cannot see the global max.
    """
    k = pl.program_id(2)
    ba = scal_ref[0, 0]
    bw = scal_ref[0, 1]
    sa = scal_ref[0, 2]
    swt = scal_ref[0, 3]  # max|tanh(w)| — tn normalization
    sww = scal_ref[0, 4]  # max|w|      — rescale, matches weight_quant

    a = a_ref[...]
    w = w_ref[...]

    na = jnp.maximum(jnp.exp2(ba) - 1.0, 1.0)
    nw = jnp.maximum(jnp.exp2(bw) - 1.0, 1.0)

    an = jnp.clip(a / sa, 0.0, 1.0)
    aq = jnp.where(ba > 0, jnp.round(an * na) / na * sa, a)

    t = jnp.tanh(w)
    tn = t / (2.0 * jnp.maximum(swt, 1e-8)) + 0.5
    wq = jnp.where(bw > 0, (2.0 * (jnp.round(tn * nw) / nw) - 1.0) * sww, w)

    acc = jnp.dot(aq, wq, preferred_element_type=jnp.float32)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = acc

    @pl.when(k > 0)
    def _acc():
        o_ref[...] += acc


def qmatmul_tiled(a, w, bits_a, bits_w, bm=128, bn=128, bk=128):
    """Tiled fused fake-quantized matmul for MXU-aligned operands.

    Requires ``M % bm == K % bk == N % bn == 0`` (callers pad).  VMEM
    footprint per grid step = (bm*bk + bk*bn + bm*bn) * 4 bytes — e.g.
    128^2 * 3 * 4 = 192 KiB, comfortably under the ~16 MiB VMEM budget,
    leaving room for double-buffering the HBM->VMEM pipeline.
    """
    M, K = a.shape
    K2, N = w.shape
    assert K == K2 and M % bm == 0 and K % bk == 0 and N % bn == 0

    # Per-tensor scales (global reductions happen outside the block kernel).
    sa = jnp.maximum(jnp.max(jnp.abs(a)), 1e-8)
    # Weight path folds max|tanh(w)| into the scalar so the kernel's
    # normalization matches weight_quant: sw_norm for tn, max|w| for rescale.
    swt = jnp.maximum(jnp.max(jnp.abs(jnp.tanh(w))), 1e-8)
    sww = jnp.maximum(jnp.max(jnp.abs(w)), 1e-8)
    scal = jnp.stack([
        bits_a.astype(jnp.float32),
        bits_w.astype(jnp.float32),
        sa,
        swt,
        sww,
    ]).reshape(1, 5)

    grid = (M // bm, N // bn, K // bk)
    out = pl.pallas_call(
        _qmatmul_tiled_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, 5), lambda i, j, k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        interpret=True,
    )(a, w, scal)
    return out
