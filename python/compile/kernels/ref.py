"""Pure-jnp oracles for the Pallas kernels.

These are the ground truth the kernels are tested against (pytest +
hypothesis) and the recomputation path of the qmatmul backward pass.
No pallas imports here — this module must stay a plain-jnp reference.
"""

import jax.numpy as jnp


def quantize_k_ref(x, bits):
    """round(x * n) / n with n = 2**bits - 1; identity when bits == 0."""
    n = jnp.maximum(jnp.exp2(jnp.asarray(bits, jnp.float32)) - 1.0, 1.0)
    return jnp.where(bits > 0, jnp.round(x * n) / n, x)


def weight_quant_ref(w, bits):
    """DoReFa weight fake-quant with max|w| rescale (see fake_quant.py)."""
    t = jnp.tanh(w)
    m = jnp.maximum(jnp.max(jnp.abs(t)), 1e-8)
    tn = t / (2.0 * m) + 0.5
    s = jnp.maximum(jnp.max(jnp.abs(w)), 1e-8)
    wq = (2.0 * quantize_k_ref(tn, bits) - 1.0) * s
    return jnp.where(bits > 0, wq, w)


def act_quant_ref(a, bits):
    """Dynamic per-tensor-scale activation fake-quant (see fake_quant.py)."""
    s = jnp.maximum(jnp.max(jnp.abs(a)), 1e-8)
    an = jnp.clip(a / s, 0.0, 1.0)
    aq = quantize_k_ref(an, bits) * s
    return jnp.where(bits > 0, aq, a)


def qmatmul_ref(a, w, bits_a, bits_w):
    """act_quant(a) @ weight_quant(w) in plain jnp."""
    return act_quant_ref(a, bits_a) @ weight_quant_ref(w, bits_w)
