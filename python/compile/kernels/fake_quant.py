"""L1 Pallas kernel: uniform fixed-point fake-quantization with runtime bit-width.

This is the inner primitive of quantization-aware training (QAT) in the
Chain of Compression: every weight and activation in a quantized network
passes through ``quantize_k`` (DoReFa-style ``quantize_k`` from Zhou et al.
2016).  The bit-width is a *runtime scalar operand* so a single AOT-lowered
graph serves every point of the chain (``bits == 0`` disables quantization,
i.e. the fp32 path).

The kernel is written for TPU-style execution (elementwise VPU op over a
VMEM-resident block) but is lowered with ``interpret=True`` so the emitted
HLO runs on any PJRT backend, including the rust CPU client on the request
path.  See DESIGN.md §Hardware-Adaptation.

Straight-through estimation (STE) lives here too: ``quantize_k`` carries a
``jax.custom_vjp`` whose backward pass is the identity w.r.t. ``x`` — the
classic STE of DoReFa-Net.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quantize_kernel(x_ref, bits_ref, o_ref):
    """Pallas kernel body: o = round(x * n) / n with n = 2**bits - 1.

    ``bits`` arrives as a (1, 1) f32 scalar block.  ``n`` is clamped to >= 1
    so the ``bits == 0`` (quantization off) case stays finite; the caller
    selects the un-quantized input in that case (see ``quantize_k``).
    """
    x = x_ref[...]
    bits = bits_ref[0, 0]
    n = jnp.maximum(jnp.exp2(bits) - 1.0, 1.0)
    o_ref[...] = jnp.round(x * n) / n


def _quantize_pallas(x2d, bits11):
    """Single-block elementwise quantize over a 2-D view of ``x``.

    Model tensors here are small (<= a few MB) so a single VMEM block
    suffices; the tiled variant for large operands is ``qmatmul`` which
    fuses quantization into the matmul block loop.
    """
    return pl.pallas_call(
        _quantize_kernel,
        out_shape=jax.ShapeDtypeStruct(x2d.shape, x2d.dtype),
        interpret=True,
    )(x2d, bits11)


@partial(jax.custom_vjp, nondiff_argnums=())
def quantize_k(x, bits):
    """DoReFa ``quantize_k``: uniform quantization of ``x`` in [0, 1] to
    ``2**bits`` levels; identity when ``bits == 0``.  STE backward."""
    shape = x.shape
    x2d = x.reshape(1, -1) if x.ndim != 2 else x
    bits11 = jnp.reshape(bits.astype(jnp.float32), (1, 1))
    q = _quantize_pallas(x2d, bits11).reshape(shape)
    return jnp.where(bits > 0, q, x)


def _quantize_k_fwd(x, bits):
    return quantize_k(x, bits), None


def _quantize_k_bwd(_, g):
    # Straight-through: d quantize_k / d x := 1.  No gradient to bits.
    return g, jnp.zeros(())


quantize_k.defvjp(_quantize_k_fwd, _quantize_k_bwd)


def weight_quant(w, bits):
    """DoReFa-style weight fake-quantization with magnitude rescale.

    tanh-normalize to [0, 1], quantize to ``bits`` levels, map back to
    [-s, s] where ``s = max|w|`` (stop-grad) so the quantized weights keep
    the tensor's dynamic range — this keeps the ``bits on/off`` switch a
    perturbation QAT can recover from, mirroring the paper's
    quantize-then-fine-tune protocol.
    """
    t = jnp.tanh(w)
    m = jnp.maximum(jnp.max(jnp.abs(t)), 1e-8)
    tn = t / (2.0 * m) + 0.5
    s = jax.lax.stop_gradient(jnp.maximum(jnp.max(jnp.abs(w)), 1e-8))
    wq = (2.0 * quantize_k(tn, bits) - 1.0) * s
    return jnp.where(bits > 0, wq, w)


def act_quant(a, bits):
    """Activation fake-quantization with per-tensor dynamic scale.

    Post-ReLU activations are >= 0; scale by the (stop-grad) tensor max,
    clip to [0, 1], quantize, rescale.  This is fixed-point uniform
    activation quantization with a dynamic per-tensor scale — the
    hardware-friendly scheme the paper adopts.
    """
    s = jax.lax.stop_gradient(jnp.maximum(jnp.max(jnp.abs(a)), 1e-8))
    an = jnp.clip(a / s, 0.0, 1.0)
    aq = quantize_k(an, bits) * s
    return jnp.where(bits > 0, aq, a)
