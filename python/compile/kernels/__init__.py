"""L1: Pallas kernels for the Chain of Compression QAT hot-spot."""

from .fake_quant import quantize_k, weight_quant, act_quant
from .qmatmul import qmatmul, qmatmul_tiled
from . import ref

__all__ = [
    "quantize_k", "weight_quant", "act_quant",
    "qmatmul", "qmatmul_tiled", "ref",
]
