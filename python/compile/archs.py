"""L2: architecture definitions — MiniVGG / MiniResNet / MiniMobileNet.

These are CIFAR-family CNNs scaled to the testbed (see DESIGN.md
§Substitutions): each keeps the structural signature of the full model the
paper evaluates (plain deep VGG stack / residual basic blocks /
depthwise-separable inverted bottlenecks) so the four compression axes —
Distillation (architecture), Pruning (channel), Quantization (bit),
Early-exit (depth) — act exactly where they act in the paper.

Every architecture is expressed once, as a registry of layers plus
explicit segment-forward functions; the same registry drives

* parameter initialization (He / Kaiming),
* the jitted forward/backward (via the L1 Pallas quantizers),
* the ``manifest.json`` descriptors from which the rust coordinator does
  all BitOps / storage accounting.

Compression knobs are *runtime operands* (see DESIGN.md): channel ``masks``
(one f32 vector per mask slot), ``qbits_w`` / ``qbits_a`` scalars.  A single
AOT artifact therefore serves every state of the compression chain.

Each net is split into three segments with an early-exit head after
segment 1 and segment 2:

    x -> seg1 -> [exit1 head]
          `----> seg2 -> [exit2 head]
                  `----> seg3 -> main logits

Staged artifacts cut the graph at these boundaries so the rust serving
loop can genuinely skip seg2/seg3 when an exit fires.
"""

import jax
import jax.numpy as jnp
from jax import lax

from .kernels import weight_quant, act_quant, qmatmul

NUM_CLASSES = 20
IMG_HW = 16
IMG_C = 3


def _rmsnorm(x, mask=None):
    """Parameter-free per-sample RMS normalization over (H, W, C).

    Stabilizes the deep fp32->low-bit transitions without batch statistics
    (keeps graphs stateless — no running means to thread through PJRT).
    Costs O(HWC) adds, negligible against conv BitOps; excluded from
    BitOps accounting like the paper excludes normalization layers.

    When ``mask`` (a per-channel 0/1 vector) is given, ``x`` is assumed
    already masked and the statistic is computed over *live channels only*
    — this keeps masked networks numerically identical to physically
    pruned ones (see test_archs.py::TestMasks).
    """
    if mask is None:
        ms = jnp.mean(jnp.square(x), axis=(1, 2, 3), keepdims=True)
    else:
        live = jnp.maximum(jnp.sum(mask), 1.0)
        denom = x.shape[1] * x.shape[2] * live
        ms = jnp.sum(jnp.square(x), axis=(1, 2, 3), keepdims=True) / denom
    return x * lax.rsqrt(ms + 1e-6)


def _dw_geom(H, W, stride):
    """SAME-padding geometry shared by the depthwise fwd and bwd passes."""
    ho = -(-H // stride)
    wo = -(-W // stride)
    # XLA SAME padding: total = (out-1)*stride + k - in, split low = total//2.
    th = max((ho - 1) * stride + 3 - H, 0)
    tw = max((wo - 1) * stride + 3 - W, 0)
    return ho, wo, th, tw


from functools import partial as _partial


@_partial(jax.custom_vjp, nondiff_argnums=(2,))
def _depthwise3x3(x, w, stride):
    """Depthwise 3x3 conv (SAME) as a sum of 9 shifted elementwise products.

    ``x``: (B, H, W, C); ``w``: (3, 3, 1, C).  Equivalent to
    ``lax.conv_general_dilated(..., feature_group_count=C)`` (tested against
    it) but avoids XLA CPU's slow grouped-conv path.  The backward pass is
    hand-written in the same shifted-elementwise form (pads and slices only
    — no scatters), which is ~5x faster through XLA CPU than autodiff of
    the strided slices.
    """
    H, W = x.shape[1], x.shape[2]
    ho, wo, th, tw = _dw_geom(H, W, stride)
    xp = jnp.pad(x, ((0, 0), (th // 2, th - th // 2), (tw // 2, tw - tw // 2), (0, 0)))
    y = None
    for dy in range(3):
        for dx in range(3):
            patch = xp[:, dy:dy + (ho - 1) * stride + 1:stride,
                       dx:dx + (wo - 1) * stride + 1:stride, :] * w[dy, dx, 0, :]
            y = patch if y is None else y + patch
    return y


def _depthwise3x3_fwd(x, w, stride):
    return _depthwise3x3(x, w, stride), (x, w)


def _depthwise3x3_bwd(stride, res, g):
    x, w = res
    B, H, W, C = x.shape
    ho, wo, th, tw = _dw_geom(H, W, stride)
    Hp, Wp = H + th, W + tw
    gh, gw = (ho - 1) * stride + 1, (wo - 1) * stride + 1
    xp = jnp.pad(x, ((0, 0), (th // 2, th - th // 2), (tw // 2, tw - tw // 2), (0, 0)))

    # Dilate g to stride spacing with pads + reshape (no scatter).
    if stride > 1:
        gd = jnp.pad(g[:, :, None, :, None, :],
                     ((0, 0), (0, 0), (0, stride - 1), (0, 0), (0, stride - 1), (0, 0)))
        gd = gd.reshape(B, ho * stride, wo * stride, C)[:, :gh, :gw, :]
    else:
        gd = g

    dw_rows = []
    dxp = jnp.zeros((B, Hp, Wp, C), x.dtype)
    for dy in range(3):
        dw_cols = []
        for dx in range(3):
            patch = xp[:, dy:dy + gh:stride, dx:dx + gw:stride, :]
            dw_cols.append(jnp.sum(patch * g, axis=(0, 1, 2)))
            dxp = dxp + jnp.pad(gd * w[dy, dx, 0, :],
                                ((0, 0), (dy, Hp - gh - dy), (dx, Wp - gw - dx), (0, 0)))
        dw_rows.append(jnp.stack(dw_cols))
    dw = jnp.stack(dw_rows)[:, :, None, :]
    dx_ = dxp[:, th // 2:th // 2 + H, tw // 2:tw // 2 + W, :]
    return dx_, dw


_depthwise3x3.defvjp(_depthwise3x3_fwd, _depthwise3x3_bwd)


class Net:
    """Layer registry + manifest description for one architecture."""

    def __init__(self, name):
        self.name = name
        self.layers = []       # descriptor dicts, one per parameterized layer
        self.mask_slots = []   # {name, channels}
        # (H, W, C) feature-map shapes at the two exit cut points; set by
        # each subclass so staged graphs can be lowered at any batch size
        # (the serving micro-batcher needs batched stage artifacts).
        self.exit_cuts = None  # ((h1, w1, c1), (h2, w2, c2))

    def exit_shapes(self, batch):
        """NHWC shapes of (h1, h2) at the exit cut points for ``batch``."""
        if self.exit_cuts is None:
            raise ValueError(f"{self.name} does not declare exit_cuts")
        (h1, h2) = self.exit_cuts
        return (batch,) + tuple(h1), (batch,) + tuple(h2)

    # ----- construction ---------------------------------------------------

    def add_mask(self, name, channels):
        self.mask_slots.append({"name": name, "channels": int(channels)})
        return len(self.mask_slots) - 1

    def conv(self, name, cin, cout, k, stride, hout, wout,
             in_mask=-1, out_mask=-1, depthwise=False, segment="seg1"):
        self.layers.append({
            "name": name, "kind": "dwconv" if depthwise else "conv",
            "k": k, "cin": int(cin), "cout": int(cout), "stride": stride,
            "hout": int(hout), "wout": int(wout),
            "in_mask": in_mask, "out_mask": out_mask, "segment": segment,
        })
        return len(self.layers) - 1

    def dense(self, name, fin, fout, in_mask=-1, segment="seg3"):
        self.layers.append({
            "name": name, "kind": "dense", "k": 1,
            "cin": int(fin), "cout": int(fout), "stride": 1,
            "hout": 1, "wout": 1,
            "in_mask": in_mask, "out_mask": -1, "segment": segment,
        })
        return len(self.layers) - 1

    # ----- parameters -----------------------------------------------------

    def param_shapes(self):
        """Flat parameter list: (w, b) per layer, in registry order."""
        shapes = []
        for l in self.layers:
            if l["kind"] == "dense":
                shapes.append((l["cin"], l["cout"]))
            elif l["kind"] == "dwconv":
                shapes.append((l["k"], l["k"], 1, l["cout"]))
            else:
                shapes.append((l["k"], l["k"], l["cin"], l["cout"]))
            shapes.append((l["cout"],))
        return shapes

    def init_params(self, key):
        params = []
        for l in self.layers:
            key, sub = jax.random.split(key)
            if l["kind"] == "dense":
                fan_in = l["cin"]
                w = jax.random.normal(sub, (l["cin"], l["cout"]), jnp.float32)
            elif l["kind"] == "dwconv":
                fan_in = l["k"] * l["k"]
                w = jax.random.normal(sub, (l["k"], l["k"], 1, l["cout"]), jnp.float32)
            else:
                fan_in = l["k"] * l["k"] * l["cin"]
                w = jax.random.normal(sub, (l["k"], l["k"], l["cin"], l["cout"]), jnp.float32)
            params.append(w * jnp.sqrt(2.0 / fan_in))
            params.append(jnp.zeros((l["cout"],), jnp.float32))
        return params

    # ----- forward helpers --------------------------------------------------

    def _wb(self, params, idx):
        return params[2 * idx], params[2 * idx + 1]

    def apply_conv(self, idx, x, params, masks, qbw, qba,
                   act=True, norm=True, mask=True, quant_act=True):
        """conv -> (+bias) -> channel mask -> rmsnorm(live) -> relu -> act_quant.

        The mask is applied *before* normalization and the RMS statistic is
        taken over live channels only, so a masked network is numerically
        identical to the physically-pruned network (same forward, zero
        gradients into dead channels).  ``mask=False`` only skips the
        redundant post-activation re-mask used by residual callers; the
        pre-norm mask always applies when the layer has an ``out_mask``.
        """
        l = self.layers[idx]
        w, b = self._wb(params, idx)
        wq = weight_quant(w, qbw)
        if l["kind"] == "dwconv":
            # Depthwise 3x3 as 9 shifted elementwise MACs: XLA CPU lowers
            # grouped convolutions to a slow per-group loop, while this
            # form fuses into vectorized elementwise ops (~4x faster here);
            # on TPU both map to the same VPU work.
            y = _depthwise3x3(x, wq, l["stride"])
        else:
            y = lax.conv_general_dilated(
                x, wq, (l["stride"], l["stride"]), "SAME",
                dimension_numbers=("NHWC", "HWIO", "NHWC"))
        y = y + b
        mvec = masks[l["out_mask"]] if l["out_mask"] >= 0 else None
        if mvec is not None:
            y = y * mvec
        if norm:
            y = _rmsnorm(y, mvec)
        if act:
            y = jax.nn.relu(y)
            if quant_act:
                y = act_quant(y, qba)
        return y

    def finish_block(self, y, skip, out_mask, masks, qba):
        """Residual join: relu(y + skip) -> act_quant -> mask."""
        y = jax.nn.relu(y + skip)
        y = act_quant(y, qba)
        if out_mask >= 0:
            y = y * masks[out_mask]
        return y

    def apply_dense(self, idx, x, params, qbw, qba):
        """Fused fake-quantized matmul head (L1 qmatmul kernel)."""
        w, b = self._wb(params, idx)
        return qmatmul(x, w, qba, qbw) + b

    # ----- manifest ---------------------------------------------------------

    def describe(self):
        return {
            "name": self.name,
            "num_classes": NUM_CLASSES,
            "input": {"h": IMG_HW, "w": IMG_HW, "c": IMG_C},
            "mask_slots": self.mask_slots,
            "layers": self.layers,
            "param_shapes": [list(s) for s in self.param_shapes()],
        }


def _gap(x):
    return jnp.mean(x, axis=(1, 2))


# ===========================================================================
# MiniVGG — plain deep stack (VGG19 analog).
# ===========================================================================

class MiniVGG(Net):
    def __init__(self):
        super().__init__("mini_vgg")
        self.exit_cuts = ((8, 8, 16), (4, 4, 32))
        m = self.add_mask
        self.m_c1 = m("c1", 16); self.m_c2 = m("c2", 16)
        self.m_c3 = m("c3", 32); self.m_c4 = m("c4", 32)
        self.m_c5 = m("c5", 64); self.m_c6 = m("c6", 64)
        c = self.conv
        self.c1 = c("c1", 3, 16, 3, 1, 16, 16, -1, self.m_c1, segment="seg1")
        self.c2 = c("c2", 16, 16, 3, 1, 16, 16, self.m_c1, self.m_c2, segment="seg1")
        self.c3 = c("c3", 16, 32, 3, 1, 8, 8, self.m_c2, self.m_c3, segment="seg2")
        self.c4 = c("c4", 32, 32, 3, 1, 8, 8, self.m_c3, self.m_c4, segment="seg2")
        self.c5 = c("c5", 32, 64, 3, 1, 4, 4, self.m_c4, self.m_c5, segment="seg3")
        self.c6 = c("c6", 64, 64, 3, 1, 4, 4, self.m_c5, self.m_c6, segment="seg3")
        self.fc = self.dense("fc", 64, NUM_CLASSES, self.m_c6, segment="seg3")
        self.x1 = self.dense("exit1_fc", 16, NUM_CLASSES, self.m_c2, segment="exit1")
        self.x2 = self.dense("exit2_fc", 32, NUM_CLASSES, self.m_c4, segment="exit2")

    def seg1(self, params, masks, x, qbw, qba):
        h = self.apply_conv(self.c1, x, params, masks, qbw, qba)
        h = self.apply_conv(self.c2, h, params, masks, qbw, qba)
        return lax.reduce_window(h, -jnp.inf, lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")

    def seg2(self, params, masks, h, qbw, qba):
        h = self.apply_conv(self.c3, h, params, masks, qbw, qba)
        h = self.apply_conv(self.c4, h, params, masks, qbw, qba)
        return lax.reduce_window(h, -jnp.inf, lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")

    def seg3(self, params, masks, h, qbw, qba):
        h = self.apply_conv(self.c5, h, params, masks, qbw, qba)
        h = self.apply_conv(self.c6, h, params, masks, qbw, qba)
        return self.apply_dense(self.fc, _gap(h), params, qbw, qba)

    def exit1(self, params, h, qbw, qba):
        return self.apply_dense(self.x1, _gap(h), params, qbw, qba)

    def exit2(self, params, h, qbw, qba):
        return self.apply_dense(self.x2, _gap(h), params, qbw, qba)


# ===========================================================================
# MiniResNet — residual basic blocks (ResNet34 analog).
# ===========================================================================

class MiniResNet(Net):
    def __init__(self):
        super().__init__("mini_resnet")
        self.exit_cuts = ((16, 16, 16), (8, 8, 32))
        m = self.add_mask
        # Stage masks are shared across every output feeding a residual sum
        # (standard channel-pruning treatment of identity skips); block
        # conv1 gets a private mask.
        self.m_s1 = m("stage1", 16)
        self.m_b11 = m("b11_mid", 16); self.m_b12 = m("b12_mid", 16)
        self.m_s2 = m("stage2", 32)
        self.m_b21 = m("b21_mid", 32); self.m_b22 = m("b22_mid", 32)
        self.m_s3 = m("stage3", 64)
        self.m_b31 = m("b31_mid", 64); self.m_b32 = m("b32_mid", 64)

        c = self.conv
        self.stem = c("stem", 3, 16, 3, 1, 16, 16, -1, self.m_s1, segment="seg1")
        # stage1: two blocks @16ch, 16x16
        self.b11a = c("b11a", 16, 16, 3, 1, 16, 16, self.m_s1, self.m_b11, segment="seg1")
        self.b11b = c("b11b", 16, 16, 3, 1, 16, 16, self.m_b11, self.m_s1, segment="seg1")
        self.b12a = c("b12a", 16, 16, 3, 1, 16, 16, self.m_s1, self.m_b12, segment="seg1")
        self.b12b = c("b12b", 16, 16, 3, 1, 16, 16, self.m_b12, self.m_s1, segment="seg1")
        # stage2: downsample block + identity block @32ch, 8x8
        self.b21a = c("b21a", 16, 32, 3, 2, 8, 8, self.m_s1, self.m_b21, segment="seg2")
        self.b21b = c("b21b", 32, 32, 3, 1, 8, 8, self.m_b21, self.m_s2, segment="seg2")
        self.b21p = c("b21p", 16, 32, 1, 2, 8, 8, self.m_s1, self.m_s2, segment="seg2")
        self.b22a = c("b22a", 32, 32, 3, 1, 8, 8, self.m_s2, self.m_b22, segment="seg2")
        self.b22b = c("b22b", 32, 32, 3, 1, 8, 8, self.m_b22, self.m_s2, segment="seg2")
        # stage3: downsample block + identity block @64ch, 4x4
        self.b31a = c("b31a", 32, 64, 3, 2, 4, 4, self.m_s2, self.m_b31, segment="seg3")
        self.b31b = c("b31b", 64, 64, 3, 1, 4, 4, self.m_b31, self.m_s3, segment="seg3")
        self.b31p = c("b31p", 32, 64, 1, 2, 4, 4, self.m_s2, self.m_s3, segment="seg3")
        self.b32a = c("b32a", 64, 64, 3, 1, 4, 4, self.m_s3, self.m_b32, segment="seg3")
        self.b32b = c("b32b", 64, 64, 3, 1, 4, 4, self.m_b32, self.m_s3, segment="seg3")
        self.fc = self.dense("fc", 64, NUM_CLASSES, self.m_s3, segment="seg3")
        self.x1 = self.dense("exit1_fc", 16, NUM_CLASSES, self.m_s1, segment="exit1")
        self.x2 = self.dense("exit2_fc", 32, NUM_CLASSES, self.m_s2, segment="exit2")

    def _block(self, a_idx, b_idx, h, params, masks, qbw, qba, out_mask, proj_idx=None):
        mid = self.apply_conv(a_idx, h, params, masks, qbw, qba)
        out = self.apply_conv(b_idx, mid, params, masks, qbw, qba,
                              act=False, mask=False)
        skip = h if proj_idx is None else self.apply_conv(
            proj_idx, h, params, masks, qbw, qba, act=False, mask=False)
        return self.finish_block(out, skip, out_mask, masks, qba)

    def seg1(self, params, masks, x, qbw, qba):
        h = self.apply_conv(self.stem, x, params, masks, qbw, qba)
        h = self._block(self.b11a, self.b11b, h, params, masks, qbw, qba, self.m_s1)
        h = self._block(self.b12a, self.b12b, h, params, masks, qbw, qba, self.m_s1)
        return h

    def seg2(self, params, masks, h, qbw, qba):
        h = self._block(self.b21a, self.b21b, h, params, masks, qbw, qba,
                        self.m_s2, proj_idx=self.b21p)
        h = self._block(self.b22a, self.b22b, h, params, masks, qbw, qba, self.m_s2)
        return h

    def seg3(self, params, masks, h, qbw, qba):
        h = self._block(self.b31a, self.b31b, h, params, masks, qbw, qba,
                        self.m_s3, proj_idx=self.b31p)
        h = self._block(self.b32a, self.b32b, h, params, masks, qbw, qba, self.m_s3)
        return self.apply_dense(self.fc, _gap(h), params, qbw, qba)

    def exit1(self, params, h, qbw, qba):
        return self.apply_dense(self.x1, _gap(h), params, qbw, qba)

    def exit2(self, params, h, qbw, qba):
        return self.apply_dense(self.x2, _gap(h), params, qbw, qba)


# ===========================================================================
# MiniMobileNet — inverted residual bottlenecks (MobileNetV2 analog).
# ===========================================================================

class MiniMobileNet(Net):
    """Width-scaled MobileNetV2 analog: expand(1x1) -> depthwise(3x3) ->
    project(1x1); residual when stride 1 and cin == cout.  The paper's
    MobileNetV2 student scales width, which is exactly what the expansion
    and output masks express."""

    def __init__(self):
        super().__init__("mini_mobilenet")
        self.exit_cuts = ((8, 8, 32), (4, 4, 64))
        m = self.add_mask
        self.m_stem = m("stem", 16)
        self.m_e1 = m("b1_exp", 32); self.m_o1 = m("b1_out", 24)
        self.m_e2 = m("b2_exp", 48); self.m_o2 = m("b2_out", 32)
        self.m_e3 = m("b3_exp", 64); self.m_o3 = m("b3_out", 64)
        self.m_e4 = m("b4_exp", 128); self.m_o4 = m("b4_out", 96)
        self.m_e5 = m("b5_exp", 192)  # block5 output shares m_o4 (residual)

        c = self.conv
        self.stem = c("stem", 3, 16, 3, 1, 16, 16, -1, self.m_stem, segment="seg1")
        # block1: 16 -> 24, s1, 16x16
        self.b1e = c("b1e", 16, 32, 1, 1, 16, 16, self.m_stem, self.m_e1, segment="seg1")
        self.b1d = c("b1d", 32, 32, 3, 1, 16, 16, self.m_e1, self.m_e1, depthwise=True, segment="seg1")
        self.b1p = c("b1p", 32, 24, 1, 1, 16, 16, self.m_e1, self.m_o1, segment="seg1")
        # block2: 24 -> 32, s2, 8x8   (exit1 after this)
        self.b2e = c("b2e", 24, 48, 1, 1, 16, 16, self.m_o1, self.m_e2, segment="seg1")
        self.b2d = c("b2d", 48, 48, 3, 2, 8, 8, self.m_e2, self.m_e2, depthwise=True, segment="seg1")
        self.b2p = c("b2p", 48, 32, 1, 1, 8, 8, self.m_e2, self.m_o2, segment="seg1")
        # block3: 32 -> 64, s2, 4x4   (exit2 after this)
        self.b3e = c("b3e", 32, 64, 1, 1, 8, 8, self.m_o2, self.m_e3, segment="seg2")
        self.b3d = c("b3d", 64, 64, 3, 2, 4, 4, self.m_e3, self.m_e3, depthwise=True, segment="seg2")
        self.b3p = c("b3p", 64, 64, 1, 1, 4, 4, self.m_e3, self.m_o3, segment="seg2")
        # block4: 64 -> 96, s1, 4x4
        self.b4e = c("b4e", 64, 128, 1, 1, 4, 4, self.m_o3, self.m_e4, segment="seg3")
        self.b4d = c("b4d", 128, 128, 3, 1, 4, 4, self.m_e4, self.m_e4, depthwise=True, segment="seg3")
        self.b4p = c("b4p", 128, 96, 1, 1, 4, 4, self.m_e4, self.m_o4, segment="seg3")
        # block5: 96 -> 96, s1, residual, 4x4
        self.b5e = c("b5e", 96, 192, 1, 1, 4, 4, self.m_o4, self.m_e5, segment="seg3")
        self.b5d = c("b5d", 192, 192, 3, 1, 4, 4, self.m_e5, self.m_e5, depthwise=True, segment="seg3")
        self.b5p = c("b5p", 192, 96, 1, 1, 4, 4, self.m_e5, self.m_o4, segment="seg3")
        self.fc = self.dense("fc", 96, NUM_CLASSES, self.m_o4, segment="seg3")
        self.x1 = self.dense("exit1_fc", 32, NUM_CLASSES, self.m_o2, segment="exit1")
        self.x2 = self.dense("exit2_fc", 64, NUM_CLASSES, self.m_o3, segment="exit2")

    def _ir_block(self, e, d, p, h, params, masks, qbw, qba, out_mask, residual=False):
        y = self.apply_conv(e, h, params, masks, qbw, qba)
        y = self.apply_conv(d, y, params, masks, qbw, qba)
        y = self.apply_conv(p, y, params, masks, qbw, qba, act=False, mask=False)
        if residual:
            return self.finish_block(y, h, out_mask, masks, qba)
        # Linear bottleneck output (no relu on project, as in MBv2);
        # quantize and mask directly.
        y = act_quant(y, qba)
        if out_mask >= 0:
            y = y * masks[out_mask]
        return y

    def seg1(self, params, masks, x, qbw, qba):
        h = self.apply_conv(self.stem, x, params, masks, qbw, qba)
        h = self._ir_block(self.b1e, self.b1d, self.b1p, h, params, masks, qbw, qba, self.m_o1)
        h = self._ir_block(self.b2e, self.b2d, self.b2p, h, params, masks, qbw, qba, self.m_o2)
        return h

    def seg2(self, params, masks, h, qbw, qba):
        return self._ir_block(self.b3e, self.b3d, self.b3p, h, params, masks, qbw, qba, self.m_o3)

    def seg3(self, params, masks, h, qbw, qba):
        h = self._ir_block(self.b4e, self.b4d, self.b4p, h, params, masks, qbw, qba, self.m_o4)
        h = self._ir_block(self.b5e, self.b5d, self.b5p, h, params, masks, qbw, qba,
                           self.m_o4, residual=True)
        return self.apply_dense(self.fc, _gap(h), params, qbw, qba)

    def exit1(self, params, h, qbw, qba):
        return self.apply_dense(self.x1, _gap(h), params, qbw, qba)

    def exit2(self, params, h, qbw, qba):
        return self.apply_dense(self.x2, _gap(h), params, qbw, qba)


ARCHS = {
    "mini_vgg": MiniVGG,
    "mini_resnet": MiniResNet,
    "mini_mobilenet": MiniMobileNet,
}


def build(name):
    return ARCHS[name]()
