"""L2: training / evaluation graphs for the Chain of Compression.

Every compression knob is a runtime operand so one AOT artifact per
architecture serves the whole chain (see DESIGN.md):

* ``masks``      — channel masks (pruning + width-scaled distillation)
* ``qbw, qba``   — weight / activation fake-quant bit-widths (0 = fp32)
* ``tlogits``    — teacher logits; ``kd_alpha``/``kd_tau`` gate classic
                   Hinton KD (alpha 0 = plain CE)
* ``exit_w``     — per-exit loss weights (0 = exits untrained)
* ``hp``         — [lr, momentum, weight_decay] packed scalars

Graphs emitted per arch (all lowered by aot.py to HLO text):

  init    : seed                                  -> params ++ momenta
  train   : params ++ momenta ++ batch ++ knobs   -> params' ++ momenta' ++ [loss, acc]
  eval    : params ++ masks ++ bits ++ x          -> (logits, exit1, exit2)
  stage1  : params ++ masks ++ bits ++ x          -> (exit1 logits, h1)
  stage2  : params ++ masks ++ bits ++ h1         -> (exit2 logits, h2)
  stage3  : params ++ masks ++ bits ++ h2         -> main logits

The SGD-with-momentum update is fused into the train graph so the rust
hot loop is a single PJRT execute per step.
"""

import jax
import jax.numpy as jnp

from . import archs

TRAIN_BATCH = 32
EVAL_BATCH = 64
STAGE_BATCH = 1
# Batch sizes the staged serving graphs are lowered at.  Batch 1 is the
# contract the single-stream server relies on; larger sizes feed the
# serving micro-batcher (rust serve::batcher pads request groups to the
# largest lowered batch and falls back to batch 1 when absent).
STAGE_BATCHES = (1, 8)


def _log_softmax(z):
    zm = z - jax.lax.stop_gradient(jnp.max(z, axis=-1, keepdims=True))
    return zm - jnp.log(jnp.sum(jnp.exp(zm), axis=-1, keepdims=True))


def cross_entropy(logits, y1h):
    return -jnp.mean(jnp.sum(y1h * _log_softmax(logits), axis=-1))


def kd_loss(student_logits, teacher_logits, tau):
    """Classic Hinton distillation: tau^2 * KL(p_t^tau || p_s^tau)."""
    t = jax.nn.softmax(teacher_logits / tau)
    ls = _log_softmax(student_logits / tau)
    lt = _log_softmax(teacher_logits / tau)
    return (tau ** 2) * jnp.mean(jnp.sum(t * (lt - ls), axis=-1))


def forward_all(net, params, masks, x, qbw, qba):
    """Full forward with both exit heads."""
    h1 = net.seg1(params, masks, x, qbw, qba)
    e1 = net.exit1(params, h1, qbw, qba)
    h2 = net.seg2(params, masks, h1, qbw, qba)
    e2 = net.exit2(params, h2, qbw, qba)
    logits = net.seg3(params, masks, h2, qbw, qba)
    return logits, e1, e2


def make_loss_fn(net):
    def loss_fn(params, masks, x, y1h, qbw, qba,
                tlogits, kd_alpha, kd_tau, exit_w, wd):
        logits, e1, e2 = forward_all(net, params, masks, x, qbw, qba)
        ce = cross_entropy(logits, y1h)
        kd = kd_loss(logits, tlogits, kd_tau)
        main = (1.0 - kd_alpha) * ce + kd_alpha * kd
        # Exits learn from the data (the paper's DE finding: the student's
        # own body, not the teacher, is the right signal for exit heads).
        lexit = exit_w[0] * cross_entropy(e1, y1h) + exit_w[1] * cross_entropy(e2, y1h)
        l2 = sum(jnp.sum(jnp.square(p)) for p in params[::2])  # weights only
        loss = main + lexit + wd * l2
        acc = jnp.mean(
            (jnp.argmax(logits, -1) == jnp.argmax(y1h, -1)).astype(jnp.float32))
        return loss, acc
    return loss_fn


def make_train_step(net):
    """(params, momenta, batch, knobs) -> (params', momenta', loss, acc)."""
    loss_fn = make_loss_fn(net)

    def train_step(params, momenta, x, y1h, masks, qbw, qba,
                   tlogits, kd_alpha, kd_tau, exit_w, hp):
        lr, mu, wd = hp[0], hp[1], hp[2]
        (loss, acc), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, masks, x, y1h, qbw, qba, tlogits, kd_alpha, kd_tau, exit_w, wd)
        new_m = [mu * v + g for v, g in zip(momenta, grads)]
        new_p = [p - lr * v for p, v in zip(params, new_m)]
        return tuple(new_p) + tuple(new_m) + (loss, acc)

    return train_step


def make_eval_step(net):
    def eval_step(params, masks, x, qbw, qba):
        return forward_all(net, params, masks, x, qbw, qba)
    return eval_step


def make_stage_fns(net):
    def stage1(params, masks, x, qbw, qba):
        h1 = net.seg1(params, masks, x, qbw, qba)
        return net.exit1(params, h1, qbw, qba), h1

    def stage2(params, masks, h1, qbw, qba):
        h2 = net.seg2(params, masks, h1, qbw, qba)
        return net.exit2(params, h2, qbw, qba), h2

    def stage3(params, masks, h2, qbw, qba):
        return net.seg3(params, masks, h2, qbw, qba)

    return stage1, stage2, stage3


def make_init_fn(net):
    """seed (f32 scalar) -> params ++ zero momenta."""
    def init(seed):
        key = jax.random.PRNGKey(seed.astype(jnp.uint32))
        params = net.init_params(key)
        momenta = [jnp.zeros_like(p) for p in params]
        return tuple(params) + tuple(momenta)
    return init


# ---------------------------------------------------------------------------
# Shape helpers shared with aot.py / tests.
# ---------------------------------------------------------------------------

def mask_specs(net):
    return [jax.ShapeDtypeStruct((s["channels"],), jnp.float32)
            for s in net.mask_slots]


def param_specs(net):
    return [jax.ShapeDtypeStruct(tuple(s), jnp.float32) for s in net.param_shapes()]


def seg_out_shape(net, batch):
    """(h1, h2) feature-map shapes at the exit cut points, NHWC.

    Delegates to the architecture's declared ``exit_cuts`` so new archs
    (and new stage batch sizes) need no edits here.
    """
    return net.exit_shapes(batch)


def scalar():
    return jax.ShapeDtypeStruct((), jnp.float32)
