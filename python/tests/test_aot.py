"""AOT path tests: HLO-text lowering of a small graph + manifest schema.

Full-arch lowering takes minutes and is exercised by `make artifacts`;
here we verify the interchange path itself (jit -> stablehlo -> HLO text)
and the manifest contract on a toy function, fast.
"""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import archs, aot

jax.config.update("jax_platform_name", "cpu")


class TestHloText:
    def test_lower_tiny_fn(self):
        def f(x, y):
            return (x @ y + 1.0,)

        spec = jax.ShapeDtypeStruct((4, 4), jnp.float32)
        low = jax.jit(f).lower(spec, spec)
        txt = aot.to_hlo_text(low)
        assert txt.startswith("HloModule")
        assert "f32[4,4]" in txt
        # text interchange must not be the 64-bit-id proto path
        assert "parameter(0)" in txt

    def test_lower_pallas_kernel_graph(self):
        from compile.kernels import qmatmul

        a = jax.ShapeDtypeStruct((8, 16), jnp.float32)
        w = jax.ShapeDtypeStruct((16, 4), jnp.float32)
        s = jax.ShapeDtypeStruct((), jnp.float32)
        low = jax.jit(lambda a, w, ba, bw: (qmatmul(a, w, ba, bw),)).lower(a, w, s, s)
        txt = aot.to_hlo_text(low)
        assert txt.startswith("HloModule")
        assert "f32[8,4]" in txt


class TestManifest:
    @pytest.fixture()
    def manifest(self):
        path = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "manifest.json")
        if not os.path.exists(path):
            pytest.skip("artifacts not built (run `make artifacts`)")
        with open(path) as f:
            return json.load(f)

    def test_schema(self, manifest):
        assert manifest["num_classes"] == archs.NUM_CLASSES
        assert set(manifest["archs"]) == set(archs.ARCHS)
        for name, a in manifest["archs"].items():
            assert a["num_params"] == len(a["param_shapes"])
            assert a["num_masks"] == len(a["mask_slots"])
            for tag in ["init", "train", "eval", "stage1", "stage2", "stage3"]:
                assert tag in a["graphs"], f"{name} missing graph {tag}"
            # Micro-batched stage graphs: every declared batch > 1 must have
            # all three staged artifacts (rust falls back to batch 1 only
            # when a batch is absent entirely, not half-lowered).
            for b in a.get("stage_batches", [1]):
                assert b >= 1
                if b > 1:
                    for stage in [1, 2, 3]:
                        tag = f"stage{stage}_b{b}"
                        assert tag in a["graphs"], f"{name} missing graph {tag}"

    def test_manifest_matches_live_archs(self, manifest):
        """The manifest on disk must match what archs.py would emit now —
        guards against stale artifacts."""
        for name, a in manifest["archs"].items():
            net = archs.build(name)
            desc = net.describe()
            assert a["param_shapes"] == desc["param_shapes"], f"{name} stale artifacts?"
            assert a["mask_slots"] == desc["mask_slots"]
            assert a["layers"] == desc["layers"]

    def test_artifact_files_exist(self, manifest):
        root = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
        for a in manifest["archs"].values():
            for g in a["graphs"].values():
                p = os.path.join(root, g["file"])
                assert os.path.exists(p), f"missing {g['file']}"
                with open(p) as f:
                    assert f.read(9) == "HloModule"
