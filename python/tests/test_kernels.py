"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

The hypothesis sweeps are the core correctness signal for the fused
fake-quant matmul: random shapes / bit-widths / value ranges, always
compared against ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# Offline environments may lack hypothesis; skip this module instead of
# erroring at collection so the rest of the suite stays green.
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from compile import kernels as K
from compile.kernels import ref as R

jax.config.update("jax_platform_name", "cpu")


def _rand(key, shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape) * scale


BITS = [0.0, 1.0, 2.0, 3.0, 4.0, 8.0]


class TestQuantizeK:
    @pytest.mark.parametrize("bits", BITS)
    def test_matches_ref(self, bits):
        x = jnp.abs(_rand(0, (33, 17)))
        x = x / jnp.max(x)
        got = K.quantize_k(x, jnp.float32(bits))
        want = R.quantize_k_ref(x, jnp.float32(bits))
        np.testing.assert_allclose(got, want, atol=1e-6)

    def test_bits_zero_is_identity(self):
        x = jnp.abs(_rand(1, (8, 8)))
        np.testing.assert_allclose(K.quantize_k(x, jnp.float32(0.0)), x)

    @pytest.mark.parametrize("bits", [1.0, 2.0, 4.0])
    def test_level_count(self, bits):
        """quantize_k output takes at most 2**bits distinct values."""
        x = jnp.linspace(0, 1, 1000).reshape(10, 100)
        q = np.unique(np.asarray(K.quantize_k(x, jnp.float32(bits))))
        assert len(q) <= 2 ** int(bits)

    def test_idempotent(self):
        x = jnp.abs(_rand(2, (16, 16)))
        x = x / jnp.max(x)
        q1 = K.quantize_k(x, jnp.float32(3.0))
        q2 = K.quantize_k(q1, jnp.float32(3.0))
        np.testing.assert_allclose(q1, q2, atol=1e-6)

    def test_ste_gradient_is_identity(self):
        x = jnp.abs(_rand(3, (4, 4))) / 3.0
        g = jax.grad(lambda v: jnp.sum(K.quantize_k(v, jnp.float32(2.0))))(x)
        np.testing.assert_allclose(g, jnp.ones_like(x), atol=1e-6)

    def test_non_2d_shapes(self):
        x = jnp.abs(_rand(4, (2, 3, 5, 7)))
        x = x / jnp.max(x)
        got = K.quantize_k(x, jnp.float32(4.0))
        want = R.quantize_k_ref(x, jnp.float32(4.0))
        np.testing.assert_allclose(got, want, atol=1e-6)


class TestWeightActQuant:
    @pytest.mark.parametrize("bits", BITS)
    def test_weight_matches_ref(self, bits):
        w = _rand(5, (3, 3, 8, 16), scale=0.2)
        got = K.weight_quant(w, jnp.float32(bits))
        want = R.weight_quant_ref(w, jnp.float32(bits))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)

    @pytest.mark.parametrize("bits", BITS)
    def test_act_matches_ref(self, bits):
        a = jax.nn.relu(_rand(6, (32, 64)))
        got = K.act_quant(a, jnp.float32(bits))
        want = R.act_quant_ref(a, jnp.float32(bits))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)

    def test_weight_preserves_range(self):
        w = _rand(7, (64, 64), scale=0.5)
        for bits in [1.0, 4.0, 8.0]:
            wq = K.weight_quant(w, jnp.float32(bits))
            assert float(jnp.max(jnp.abs(wq))) <= float(jnp.max(jnp.abs(w))) * 1.001

    def test_binary_weight_two_levels_per_sign(self):
        w = _rand(8, (128,), scale=0.3)
        wq = np.asarray(K.weight_quant(w, jnp.float32(1.0)))
        assert len(np.unique(np.round(wq, 6))) <= 2

    def test_quant_error_shrinks_with_bits(self):
        w = _rand(9, (64, 64), scale=0.3)
        errs = [float(jnp.mean(jnp.abs(K.weight_quant(w, jnp.float32(b)) - w)))
                for b in [1.0, 2.0, 4.0, 8.0]]
        assert errs == sorted(errs, reverse=True)
        a = jax.nn.relu(_rand(10, (64, 64)))
        errs = [float(jnp.mean(jnp.abs(K.act_quant(a, jnp.float32(b)) - a)))
                for b in [1.0, 2.0, 4.0, 8.0]]
        assert errs == sorted(errs, reverse=True)


class TestQMatmul:
    @pytest.mark.parametrize("ba", [0.0, 2.0, 8.0])
    @pytest.mark.parametrize("bw", [0.0, 1.0, 4.0])
    def test_matches_ref(self, ba, bw):
        a = jax.nn.relu(_rand(11, (16, 24)))
        w = _rand(12, (24, 10), scale=0.3)
        got = K.qmatmul(a, w, jnp.float32(ba), jnp.float32(bw))
        want = R.qmatmul_ref(a, w, jnp.float32(ba), jnp.float32(bw))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_fp32_path_is_plain_matmul(self):
        a = _rand(13, (8, 8))
        w = _rand(14, (8, 8))
        got = K.qmatmul(a, w, jnp.float32(0.0), jnp.float32(0.0))
        np.testing.assert_allclose(got, a @ w, rtol=1e-5, atol=1e-5)

    def test_gradients_flow(self):
        a = jax.nn.relu(_rand(15, (4, 6)))
        w = _rand(16, (6, 3), scale=0.3)
        da, dw = jax.grad(
            lambda a, w: jnp.sum(K.qmatmul(a, w, jnp.float32(4.0), jnp.float32(2.0))),
            argnums=(0, 1))(a, w)
        # STE backward = plain matmul cotangents against quantized operands.
        aq = R.act_quant_ref(a, jnp.float32(4.0))
        wq = R.weight_quant_ref(w, jnp.float32(2.0))
        np.testing.assert_allclose(da, jnp.ones((4, 3)) @ wq.T, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(dw, aq.T @ jnp.ones((4, 3)), rtol=1e-4, atol=1e-5)

    @settings(max_examples=25, deadline=None)
    @given(
        m=st.integers(1, 48), k=st.integers(1, 48), n=st.integers(1, 24),
        ba=st.sampled_from([0.0, 1.0, 2.0, 4.0, 8.0]),
        bw=st.sampled_from([0.0, 1.0, 2.0, 4.0, 8.0]),
        seed=st.integers(0, 2 ** 16),
    )
    def test_hypothesis_shapes_bits(self, m, k, n, ba, bw, seed):
        key = jax.random.PRNGKey(seed)
        k1, k2 = jax.random.split(key)
        a = jax.nn.relu(jax.random.normal(k1, (m, k)))
        w = jax.random.normal(k2, (k, n)) * 0.3
        got = K.qmatmul(a, w, jnp.float32(ba), jnp.float32(bw))
        want = R.qmatmul_ref(a, w, jnp.float32(ba), jnp.float32(bw))
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


class TestQMatmulTiled:
    @pytest.mark.parametrize("bm,bn,bk", [(64, 64, 128), (128, 128, 128), (64, 128, 64)])
    def test_matches_ref(self, bm, bn, bk):
        a = jax.nn.relu(_rand(17, (128, 256)))
        w = _rand(18, (256, 128), scale=0.2)
        got = K.qmatmul_tiled(a, w, jnp.float32(8.0), jnp.float32(4.0),
                              bm=bm, bn=bn, bk=bk)
        want = R.qmatmul_ref(a, w, jnp.float32(8.0), jnp.float32(4.0))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_matches_single_block_kernel(self):
        a = jax.nn.relu(_rand(19, (128, 128)))
        w = _rand(20, (128, 128), scale=0.2)
        t = K.qmatmul_tiled(a, w, jnp.float32(4.0), jnp.float32(2.0))
        s = K.qmatmul(a, w, jnp.float32(4.0), jnp.float32(2.0))
        np.testing.assert_allclose(t, s, rtol=1e-4, atol=1e-4)

    def test_rejects_misaligned(self):
        a = jnp.ones((100, 128))
        w = jnp.ones((128, 128))
        with pytest.raises(AssertionError):
            K.qmatmul_tiled(a, w, jnp.float32(2.0), jnp.float32(2.0))

    def test_fp32_path(self):
        a = _rand(21, (128, 128))
        w = _rand(22, (128, 128))
        got = K.qmatmul_tiled(a, w, jnp.float32(0.0), jnp.float32(0.0))
        np.testing.assert_allclose(got, a @ w, rtol=1e-4, atol=1e-4)
