"""L2 architecture tests: shapes, masks, depthwise rewrite, manifest."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import lax

from compile import archs, model
from compile.archs import _depthwise3x3

jax.config.update("jax_platform_name", "cpu")

ARCH_NAMES = list(archs.ARCHS)
B0 = jnp.float32(0.0)


def setup_net(name, seed=0):
    net = archs.build(name)
    params = net.init_params(jax.random.PRNGKey(seed))
    masks = [jnp.ones((s["channels"],)) for s in net.mask_slots]
    return net, params, masks


class TestDepthwise:
    @pytest.mark.parametrize("stride", [1, 2])
    @pytest.mark.parametrize("hw", [7, 8, 16])
    def test_forward_matches_lax(self, stride, hw):
        k = jax.random.PRNGKey(0)
        x = jax.random.normal(k, (2, hw, hw, 6))
        w = jax.random.normal(jax.random.PRNGKey(1), (3, 3, 1, 6))
        want = lax.conv_general_dilated(
            x, w, (stride, stride), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"), feature_group_count=6)
        got = _depthwise3x3(x, w, stride)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("stride", [1, 2])
    def test_custom_vjp_matches_autodiff_of_lax(self, stride):
        k = jax.random.PRNGKey(2)
        x = jax.random.normal(k, (3, 8, 8, 5))
        w = jax.random.normal(jax.random.PRNGKey(3), (3, 3, 1, 5))

        def loss_ref(x, w):
            y = lax.conv_general_dilated(
                x, w, (stride, stride), "SAME",
                dimension_numbers=("NHWC", "HWIO", "NHWC"), feature_group_count=5)
            return jnp.sum(jnp.sin(y))

        def loss_ours(x, w):
            return jnp.sum(jnp.sin(_depthwise3x3(x, w, stride)))

        gx_r, gw_r = jax.grad(loss_ref, argnums=(0, 1))(x, w)
        gx, gw = jax.grad(loss_ours, argnums=(0, 1))(x, w)
        np.testing.assert_allclose(gx, gx_r, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(gw, gw_r, rtol=1e-4, atol=1e-5)


class TestForward:
    @pytest.mark.parametrize("name", ARCH_NAMES)
    def test_output_shapes(self, name):
        net, params, masks = setup_net(name)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 16, 3))
        logits, e1, e2 = model.forward_all(net, params, masks, x, B0, B0)
        assert logits.shape == (4, archs.NUM_CLASSES)
        assert e1.shape == (4, archs.NUM_CLASSES)
        assert e2.shape == (4, archs.NUM_CLASSES)
        assert not np.any(np.isnan(np.asarray(logits)))

    @pytest.mark.parametrize("name", ARCH_NAMES)
    def test_staged_equals_full(self, name):
        """stage1→stage2→stage3 must reproduce forward_all exactly."""
        net, params, masks = setup_net(name)
        x = jax.random.normal(jax.random.PRNGKey(2), (2, 16, 16, 3))
        s1, s2, s3 = model.make_stage_fns(net)
        e1s, h1 = s1(params, masks, x, B0, B0)
        e2s, h2 = s2(params, masks, h1, B0, B0)
        lo = s3(params, masks, h2, B0, B0)
        l_full, e1f, e2f = model.forward_all(net, params, masks, x, B0, B0)
        np.testing.assert_allclose(lo, l_full, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(e1s, e1f, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(e2s, e2f, rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("name", ARCH_NAMES)
    def test_stage_shapes_match_manifest(self, name):
        net, params, masks = setup_net(name)
        x = jax.random.normal(jax.random.PRNGKey(3), (1, 16, 16, 3))
        s1, s2, _ = model.make_stage_fns(net)
        _, h1 = s1(params, masks, x, B0, B0)
        _, h2 = s2(params, masks, h1, B0, B0)
        h1_want, h2_want = model.seg_out_shape(net, 1)
        assert h1.shape == h1_want
        assert h2.shape == h2_want

    @pytest.mark.parametrize("name", ARCH_NAMES)
    @pytest.mark.parametrize("batch", [1, 4, 8])
    def test_exit_cuts_match_traced_shapes(self, name, batch):
        """The declared exit_cuts (used to lower batched stage graphs for
        the serving micro-batcher) must match the actual traced shapes at
        every serving batch size — checked via eval_shape (no compile)."""
        net, params, masks = setup_net(name)
        s1, s2, _ = model.make_stage_fns(net)
        x = jax.ShapeDtypeStruct((batch, 16, 16, 3), jnp.float32)
        _, h1 = jax.eval_shape(s1, params, masks, x, B0, B0)
        _, h2 = jax.eval_shape(
            s2, params, masks,
            jax.ShapeDtypeStruct(h1.shape, jnp.float32), B0, B0)
        h1_want, h2_want = net.exit_shapes(batch)
        assert h1.shape == h1_want
        assert h2.shape == h2_want
        # seg_out_shape is the same contract, via the model module.
        assert model.seg_out_shape(net, batch) == (h1_want, h2_want)

    def test_stage_batches_include_one(self):
        assert 1 in model.STAGE_BATCHES
        assert all(b >= 1 for b in model.STAGE_BATCHES)

    @pytest.mark.parametrize("name", ARCH_NAMES)
    def test_quantized_forward_finite(self, name):
        net, params, masks = setup_net(name)
        x = jax.random.normal(jax.random.PRNGKey(4), (2, 16, 16, 3))
        for bw, ba in [(1.0, 8.0), (2.0, 2.0), (8.0, 8.0)]:
            logits, _, _ = model.forward_all(
                net, params, masks, x, jnp.float32(bw), jnp.float32(ba))
            assert np.all(np.isfinite(np.asarray(logits)))


class TestMasks:
    @pytest.mark.parametrize("name", ARCH_NAMES)
    def test_zero_mask_kills_channel_influence(self, name):
        """Zeroing a mask slot must change logits vs. ones (channels used),
        and perturbing the masked channels' weights must NOT change logits
        (channels truly dead) — the physical-removal equivalence."""
        net, params, masks = setup_net(name)
        x = jax.random.normal(jax.random.PRNGKey(5), (2, 16, 16, 3))
        base, _, _ = model.forward_all(net, params, masks, x, B0, B0)

        slot = 0
        masked = list(masks)
        m = np.ones(masks[slot].shape, np.float32)
        m[: len(m) // 2] = 0.0
        masked[slot] = jnp.asarray(m)
        out_masked, _, _ = model.forward_all(net, params, masked, x, B0, B0)
        assert not np.allclose(base, out_masked)

        # find a conv whose out_mask is this slot; perturb its masked-out
        # output channels — logits must be identical.
        li = next(i for i, l in enumerate(net.layers) if l["out_mask"] == slot)
        pert = list(params)
        w = np.asarray(pert[2 * li]).copy()
        w[..., : len(m) // 2] += 7.0
        pert[2 * li] = jnp.asarray(w)
        out_pert, _, _ = model.forward_all(net, pert, masked, x, B0, B0)
        np.testing.assert_allclose(out_masked, out_pert, rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("name", ARCH_NAMES)
    def test_masked_channels_get_zero_gradient(self, name):
        net, params, masks = setup_net(name)
        x = jax.random.normal(jax.random.PRNGKey(6), (2, 16, 16, 3))
        y = jax.nn.one_hot(jnp.array([1, 2]), archs.NUM_CLASSES)
        slot = 0
        masked = list(masks)
        m = np.ones(masks[slot].shape, np.float32)
        dead = len(m) // 2
        m[:dead] = 0.0
        masked[slot] = jnp.asarray(m)
        loss_fn = model.make_loss_fn(net)
        grads = jax.grad(
            lambda p: loss_fn(p, masked, x, y, B0, B0,
                              jnp.zeros_like(y), jnp.float32(0.0), jnp.float32(4.0),
                              jnp.zeros(2), 0.0)[0])(params)
        li = next(i for i, l in enumerate(net.layers) if l["out_mask"] == slot)
        gw = np.asarray(grads[2 * li])
        assert np.allclose(gw[..., :dead], 0.0, atol=1e-7), \
            "masked-out channels must receive zero gradient"


class TestManifestConsistency:
    @pytest.mark.parametrize("name", ARCH_NAMES)
    def test_param_shapes_match_init(self, name):
        net, params, _ = setup_net(name)
        shapes = net.param_shapes()
        assert len(shapes) == len(params)
        for s, p in zip(shapes, params):
            assert tuple(s) == p.shape

    @pytest.mark.parametrize("name", ARCH_NAMES)
    def test_mask_slots_cover_layers(self, name):
        net = archs.build(name)
        nslots = len(net.mask_slots)
        for l in net.layers:
            assert -1 <= l["in_mask"] < nslots
            assert -1 <= l["out_mask"] < nslots
            if l["out_mask"] >= 0:
                assert net.mask_slots[l["out_mask"]]["channels"] == l["cout"]
            if l["in_mask"] >= 0:
                assert net.mask_slots[l["in_mask"]]["channels"] == l["cin"]

    @pytest.mark.parametrize("name", ARCH_NAMES)
    def test_describe_is_json_serializable(self, name):
        import json
        net = archs.build(name)
        json.dumps(net.describe())


class TestTraining:
    @pytest.mark.parametrize("name", ARCH_NAMES)
    def test_overfits_tiny_batch(self, name):
        """A few SGD steps on one batch must reduce the loss by >30%."""
        net, params, masks = setup_net(name)
        k = jax.random.PRNGKey(7)
        x = jax.random.normal(k, (model.TRAIN_BATCH, 16, 16, 3))
        y = jax.nn.one_hot(
            jax.random.randint(k, (model.TRAIN_BATCH,), 0, archs.NUM_CLASSES),
            archs.NUM_CLASSES)
        ts = jax.jit(model.make_train_step(net))
        mom = [jnp.zeros_like(p) for p in params]
        tl = jnp.zeros_like(y)
        ew = jnp.array([0.0, 0.0])  # main head only: cleanest overfit signal
        hp = jnp.array([0.03, 0.9, 1e-4])
        n = len(params)
        first = None
        for i in range(40):
            out = ts(params, mom, x, y, masks, B0, B0, tl,
                     jnp.float32(0.0), jnp.float32(4.0), ew, hp)
            params, mom = list(out[:n]), list(out[n:2 * n])
            if first is None:
                first = float(out[2 * n])
        last = float(out[2 * n])
        assert last < 0.7 * first, f"{name}: loss {first} -> {last}"

    def test_kd_loss_zero_when_matching(self):
        z = jax.random.normal(jax.random.PRNGKey(8), (4, 20))
        assert abs(float(model.kd_loss(z, z, jnp.float32(4.0)))) < 1e-5

    def test_kd_loss_positive(self):
        k1, k2 = jax.random.split(jax.random.PRNGKey(9))
        s = jax.random.normal(k1, (4, 20))
        t = jax.random.normal(k2, (4, 20))
        assert float(model.kd_loss(s, t, jnp.float32(4.0))) > 0

    def test_cross_entropy_perfect_prediction(self):
        y = jax.nn.one_hot(jnp.array([0, 1]), 20)
        logits = 50.0 * y
        assert float(model.cross_entropy(logits, y)) < 1e-4
