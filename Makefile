# Chain of Compression — build entrypoints.
#
#   make artifacts   lower all AOT graphs + manifest (python runs ONCE here)
#   make build       release build of the rust coordinator
#   make test        python unit tests + rust test suite
#   make bench       rust micro/e2e benches (needs artifacts)

ARTIFACTS := artifacts

.PHONY: artifacts build test bench

artifacts:
	cd python && python -m compile.aot --out ../$(ARTIFACTS)
	@# cargo test/bench/run execute with cwd=rust/ and resolve ./artifacts
	@# relative to it; python tests resolve the repo-root copy.  One real
	@# directory, one symlink.
	ln -sfn ../$(ARTIFACTS) rust/artifacts

build:
	cd rust && cargo build --release

test:
	cd python && python -m pytest tests -q
	cd rust && cargo test -q

bench: build
	cd rust && cargo bench
