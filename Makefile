# Chain of Compression — build entrypoints.
#
#   make artifacts   lower all AOT graphs + manifest (python runs ONCE here)
#   make build       release build of the rust coordinator
#   make test        python unit tests + rust test suite
#   make verify      tier-1 (release build + cargo test) + pytest python/tests
#   make bench       rust micro/e2e benches (needs artifacts)
#   make bench-diff  gate results/ against the committed BENCH_*.json ledgers
#   make bench-simd  hermetic scalar-vs-SIMD kernel tiers (refback_kernels)
#   make serve-bench-compressed  hermetic dense-vs-compressed serving comparison
#   make chaos       deterministic fault-injection soak (hermetic ref backend)
#   make bless       re-bless BENCH_*.json ledgers from the current results/

ARTIFACTS := artifacts

.PHONY: artifacts build test verify bench bench-diff bench-simd serve-bench-compressed chaos bless

artifacts:
	cd python && python -m compile.aot --out ../$(ARTIFACTS)
	@# cargo test/bench/run execute with cwd=rust/ and resolve ./artifacts
	@# relative to it; python tests resolve the repo-root copy.  One real
	@# directory, one symlink.
	ln -sfn ../$(ARTIFACTS) rust/artifacts

build:
	cd rust && cargo build --release

test:
	cd python && python -m pytest tests -q
	cd rust && cargo test -q

# Tier-1 verification plus the python suite — the pre-merge gate.
# bench-diff only runs when results/ exists (a fresh checkout has none).
verify:
	cd rust && cargo build --release && cargo test -q
	python -m pytest python/tests -q
	@if [ -d results ]; then $(MAKE) bench-diff; else echo "verify: no results/ dir, skipping bench-diff"; fi

bench: build
	cd rust && cargo bench

# Hermetic (no artifacts): the refback kernel bench alone, which carries
# the scalar-vs-SIMD tiers and writes simd_speedup_* into
# results/refback_kernels.json.  The run also bit-checks every vector
# path against the scalar walk before timing anything.
bench-simd:
	cd rust && cargo bench -- refback_kernels

# Compare the latest results/*.json against the committed BENCH_*.json
# ledgers; exits nonzero on a regression past per-metric tolerance.
bench-diff: build
	cd rust && cargo run --release -q -- bench-diff --root .. --results ../results

# Dense vs packed (sparse/int8) serving on the hermetic ref backend: the
# same pool and load twice over a P->Q->E mini_vgg leaf.  Writes
# results/serve_bench_compressed.json (the serve_compressed ledger area).
serve-bench-compressed: build
	cd rust && cargo run --release -q -- serve-bench --backend ref --arch mini_vgg \
		--scale smoke --requests 400 --workers 2 --out ../results --compressed

# Deterministic fault-injection soak on the hermetic ref backend: panic
# storms, slow batches vs deadlines, plan quarantine, cache corruption —
# every test asserts the exactly-one-terminal-outcome invariant and the
# same-seed schedule-determinism contract (see DESIGN.md "Failure
# domains & fault injection").
chaos:
	cd rust && cargo test --test chaos -- --nocapture

# Re-bless the committed BENCH_*.json ledgers from the latest results/
# run (after an intentional perf change); review the diff like code.
bless: build
	cd rust && cargo run --release -q -- bench-diff --root .. --results ../results --update
