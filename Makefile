# Chain of Compression — build entrypoints.
#
#   make artifacts   lower all AOT graphs + manifest (python runs ONCE here)
#   make build       release build of the rust coordinator
#   make test        python unit tests + rust test suite
#   make verify      tier-1 (release build + cargo test) + pytest python/tests
#   make bench       rust micro/e2e benches (needs artifacts)

ARTIFACTS := artifacts

.PHONY: artifacts build test verify bench

artifacts:
	cd python && python -m compile.aot --out ../$(ARTIFACTS)
	@# cargo test/bench/run execute with cwd=rust/ and resolve ./artifacts
	@# relative to it; python tests resolve the repo-root copy.  One real
	@# directory, one symlink.
	ln -sfn ../$(ARTIFACTS) rust/artifacts

build:
	cd rust && cargo build --release

test:
	cd python && python -m pytest tests -q
	cd rust && cargo test -q

# Tier-1 verification plus the python suite — the pre-merge gate.
verify:
	cd rust && cargo build --release && cargo test -q
	python -m pytest python/tests -q

bench: build
	cd rust && cargo bench
