//! Early-exit serving demo: the *dynamic* compression stage at work.
//!
//! Trains exit heads on a small model, then serves single-sample requests
//! through the staged AOT graphs (stage1 -> maybe stage2 -> maybe stage3),
//! so confident requests genuinely skip computation.  Reports the
//! latency/throughput effect of the confidence threshold — the runtime
//! knob the paper sweeps.
//!
//!     make artifacts && cargo run --release --example early_exit_serving

use anyhow::Result;

use coc::chain::{stages, Chain, StageCtx};
use coc::data::{Dataset, DatasetKind};
use coc::models::Manifest;
use coc::runtime::Engine;
use coc::serve::Server;
use coc::train::{self, TrainOpts};

fn main() -> Result<()> {
    let engine = Engine::new(coc::DEFAULT_ARTIFACTS)?;
    let manifest = Manifest::load(coc::DEFAULT_ARTIFACTS)?;
    let arch = manifest.arch("mini_vgg")?;

    let train_ds = Dataset::generate(DatasetKind::SynthSVHN, 512, 7, 0);
    let test_ds = Dataset::generate(DatasetKind::SynthSVHN, 256, 7, 1);

    // Base training + exit-head training.
    let mut state = train::init_state(&engine, arch, 7)?;
    let opts = TrainOpts { steps: 180, ..Default::default() };
    train::train(&engine, &mut state, &train_ds, None, &opts)?;
    let ctx = StageCtx {
        engine: &engine,
        train: &train_ds,
        test: &test_ds,
        base_steps: 180,
        seed: 7,
        verbose: false,
    };
    Chain::new()
        .push(Box::new(stages::EarlyExit { threshold: 0.8, ..Default::default() }))
        .run(&mut state, &ctx)?;
    let acc = train::eval_accuracy(&engine, &state, &test_ds)?;
    println!("model ready: main-head acc {:.1}%", acc * 100.0);

    // Serve under different thresholds: lower threshold -> more requests
    // exit early -> lower latency, possibly lower accuracy.
    let server = Server::new(&engine, state)?;
    println!(
        "{:>9} {:>8} {:>7} {:>7} {:>10} {:>10} {:>9}",
        "threshold", "acc", "exit1", "exit2", "p50 µs", "p95 µs", "rps"
    );
    for t in [0.99f32, 0.9, 0.8, 0.65, 0.5, 0.35] {
        let rep = server.serve_dataset(&test_ds, 200, t, t)?;
        println!(
            "{:>9.2} {:>7.1}% {:>6.0}% {:>6.0}% {:>10.0} {:>10.0} {:>9.0}",
            t,
            rep.accuracy * 100.0,
            rep.p_exit1 * 100.0,
            rep.p_exit2 * 100.0,
            rep.latency_us.p50(),
            rep.latency_us.p95(),
            rep.throughput_rps
        );
    }
    Ok(())
}
