//! Early-exit serving demo: the *dynamic* compression stage at work.
//!
//! Part 1 trains exit heads on a small model and serves single-sample
//! requests through the staged AOT graphs (stage1 -> maybe stage2 ->
//! maybe stage3), sweeping the confidence threshold — the runtime knob
//! the paper sweeps.
//!
//! Part 2 puts the same model behind the concurrent serving subsystem:
//! a bounded request queue, dynamic micro-batching, and a pool of workers
//! each owning its own PJRT engine, driven closed-loop — the production
//! shape of the same early-exit policy.
//!
//!     make artifacts && cargo run --release --example early_exit_serving

use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use coc::chain::{stages, Chain, StageCtx};
use coc::data::{Dataset, DatasetKind};
use coc::models::Manifest;
use coc::runtime::Engine;
use coc::serve::batcher::BatchPolicy;
use coc::serve::loadgen::{self, LoadMode, LoadOpts};
use coc::serve::slo::Slo;
use coc::serve::worker::{PoolOpts, WorkerPool};
use coc::serve::Server;
use coc::train::{self, TrainOpts};

fn main() -> Result<()> {
    let engine = Engine::new(coc::DEFAULT_ARTIFACTS)?;
    let manifest = Manifest::load(coc::DEFAULT_ARTIFACTS)?;
    let arch = manifest.arch("mini_vgg")?;

    let train_ds = Dataset::generate(DatasetKind::SynthSVHN, 512, 7, 0);
    let test_ds = Dataset::generate(DatasetKind::SynthSVHN, 256, 7, 1);

    // Base training + exit-head training.
    let mut state = train::init_state(&engine, arch, 7)?;
    let opts = TrainOpts { steps: 180, ..Default::default() };
    train::train(&engine, &mut state, &train_ds, None, &opts)?;
    let ctx = StageCtx {
        engine: &engine,
        train: &train_ds,
        test: &test_ds,
        base_steps: 180,
        seed: 7,
        verbose: false,
    };
    Chain::new()
        .push(Box::new(stages::EarlyExit { threshold: 0.8, ..Default::default() }))
        .run(&mut state, &ctx)?;
    let acc = train::eval_accuracy(&engine, &state, &test_ds)?;
    println!("model ready: main-head acc {:.1}%", acc * 100.0);

    // ---- Part 1: single-stream threshold sweep --------------------------
    // Lower threshold -> more requests exit early -> lower latency,
    // possibly lower accuracy.
    let server = Server::new(&engine, state.clone())?;
    println!(
        "{:>9} {:>8} {:>7} {:>7} {:>10} {:>10} {:>9}",
        "threshold", "acc", "exit1", "exit2", "p50 µs", "p95 µs", "rps"
    );
    for t in [0.99f32, 0.9, 0.8, 0.65, 0.5, 0.35] {
        let rep = server.serve_dataset(&test_ds, 200, t, t)?;
        println!(
            "{:>9.2} {:>7.1}% {:>6.0}% {:>6.0}% {:>10.0} {:>10.0} {:>9.0}",
            t,
            rep.accuracy * 100.0,
            rep.p_exit1 * 100.0,
            rep.p_exit2 * 100.0,
            rep.latency_us.p50(),
            rep.latency_us.p95(),
            rep.throughput_rps
        );
    }

    // ---- Part 2: concurrent load through the worker pool ----------------
    let t = 0.8f32;
    let baseline = server.serve_dataset(&test_ds, 400, t, t)?;
    println!("\nsingle stream baseline: {:.0} rps", baseline.throughput_rps);

    for workers in [2usize, 4] {
        let mut pool_opts = PoolOpts::new(coc::DEFAULT_ARTIFACTS, workers, (t, t));
        pool_opts.batch = BatchPolicy { max_batch: 8, max_wait: Duration::from_micros(500) };
        let pool = WorkerPool::start(Arc::new(state.clone()), pool_opts);
        let up = pool.wait_ready(Duration::from_secs(300))?.ready;
        let rep = loadgen::run(
            &pool,
            &test_ds,
            &LoadOpts {
                mode: LoadMode::Closed { concurrency: 4 * workers },
                requests: 800,
                seed: 7,
                slo: Slo { latency_ms: 20.0 },
                ..Default::default()
            },
        )?;
        let outcome = pool.shutdown();
        for e in &outcome.errors {
            eprintln!("worker error: {e}");
        }
        println!(
            "{up} workers: {:.0} rps ({:.2}x single stream)  acc {:.1}%  p99 {:.0}µs  \
             goodput {:.0} rps @ {:.0}ms  queue depth max {}",
            rep.throughput_rps,
            rep.throughput_rps / baseline.throughput_rps.max(1e-9),
            rep.accuracy * 100.0,
            rep.latency_us.p99(),
            rep.slo.goodput_rps,
            rep.slo.slo_ms,
            rep.queue.max_depth
        );
    }
    Ok(())
}
