//! Quickstart: load the AOT artifacts, train a small CNN, apply one
//! compression stage, and print the paper's metrics.
//!
//!     make artifacts && cargo run --release --example quickstart

use anyhow::Result;

use coc::chain::{stages, Chain, StageCtx};
use coc::data::{Dataset, DatasetKind};
use coc::metrics::Measurement;
use coc::models::Manifest;
use coc::runtime::Engine;
use coc::train::{self, TrainOpts};

fn main() -> Result<()> {
    // 1. Engine + manifest (produced by `make artifacts`).
    let engine = Engine::new(coc::DEFAULT_ARTIFACTS)?;
    let manifest = Manifest::load(coc::DEFAULT_ARTIFACTS)?;
    let arch = manifest.arch("mini_vgg")?;
    println!("platform {}, arch {} ({} layers)", engine.platform(), arch.name, arch.layers.len());

    // 2. Synthetic CIFAR10-analog data (deterministic, seeded).
    let train_ds = Dataset::generate(DatasetKind::SynthC10, 512, 42, 0);
    let test_ds = Dataset::generate(DatasetKind::SynthC10, 128, 42, 1);

    // 3. Train a base fp32 model via the AOT train graph.
    let mut state = train::init_state(&engine, arch, 42)?;
    let opts = TrainOpts { steps: 120, log_every: 30, ..Default::default() };
    let log = train::train(&engine, &mut state, &train_ds, None, &opts)?;
    let base = Measurement::take(&engine, &state, &test_ds)?;
    println!("base model: loss {:.3}, test acc {:.1}%", log.final_loss(), base.accuracy * 100.0);

    // 4. One compression stage: 2-bit weights / 8-bit activations QAT.
    let ctx = StageCtx {
        engine: &engine,
        train: &train_ds,
        test: &test_ds,
        base_steps: 120,
        seed: 42,
        verbose: true,
    };
    let chain = Chain::new().push(Box::new(stages::Quantize {
        bits_w: 2.0,
        bits_a: 8.0,
        ..Default::default()
    }));
    let reports = chain.run(&mut state, &ctx)?;
    let m = &reports.last().unwrap().measurement;
    println!(
        "after {}: acc {:.1}%  BitOpsCR {:.1}x  storage CR {:.1}x",
        reports.last().unwrap().stage,
        m.accuracy * 100.0,
        m.bitops_cr,
        m.storage_cr
    );
    Ok(())
}
