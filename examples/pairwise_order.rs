//! Pairwise-order study in miniature (paper §3): measure one pair of
//! compression techniques in both orders and print which order's Pareto
//! frontier dominates.
//!
//!     cargo run --release --example pairwise_order [-- PQ]
//!
//! The argument names the pair by letters (default PQ = prune/quantize;
//! fastest pair since neither trains a student from scratch).
//!
//! Both orders are submitted to the plan layer (`chain::plan`): the
//! planner merges them into a prefix trie, executes each unique stage
//! once, and the content-addressed cache under /tmp makes a re-run of
//! this example near-free.

use anyhow::{anyhow, Result};

use coc::chain::plan::{EngineRunner, ExecOpts, PlanKey, Planner};
use coc::chain::Technique;
use coc::data::{Dataset, DatasetKind};
use coc::models::Manifest;
use coc::runtime::Engine;
use coc::sweep;
use coc::train::{self, TrainOpts};
use coc::util::stats;

fn main() -> Result<()> {
    let pair = std::env::args().nth(1).unwrap_or_else(|| "PQ".to_string());
    let mut letters = pair.chars();
    let a = letters
        .next()
        .and_then(Technique::from_letter)
        .ok_or_else(|| anyhow!("bad pair `{pair}`"))?;
    let b = letters
        .next()
        .and_then(Technique::from_letter)
        .ok_or_else(|| anyhow!("bad pair `{pair}`"))?;

    // The whole base-model recipe: hashed into the plan key below, so
    // editing any of these constants invalidates the persistent example
    // cache instead of replaying stale results.
    const BASE_TRAIN_STEPS: usize = 150;
    const STAGE_STEPS: usize = 100;
    const N_TRAIN: usize = 512;
    const N_TEST: usize = 128;

    let engine = Engine::new(coc::DEFAULT_ARTIFACTS)?;
    let manifest = Manifest::load(coc::DEFAULT_ARTIFACTS)?;
    let arch = manifest.arch("mini_resnet")?;
    let train_ds = Dataset::generate(DatasetKind::SynthC10, N_TRAIN, 42, 0);
    let test_ds = Dataset::generate(DatasetKind::SynthC10, N_TEST, 42, 1);

    println!("training base model...");
    let mut base = train::init_state(&engine, arch, 42)?;
    train::train(
        &engine,
        &mut base,
        &train_ds,
        None,
        &TrainOpts { steps: BASE_TRAIN_STEPS, ..Default::default() },
    )?;

    let ladder = 3;
    println!("sweeping {}{} and {}{} ...", a.letter(), b.letter(), b.letter(), a.letter());
    let mut plan = Planner::new(PlanKey {
        arch: "mini_resnet".into(),
        dataset: "c10".into(),
        scale: format!("example-b{BASE_TRAIN_STEPS}-n{N_TRAIN}x{N_TEST}"),
        base_steps: STAGE_STEPS,
        seed: 42,
    });
    sweep::submit_pairwise(&mut plan, a, b, ladder);
    sweep::submit_pairwise(&mut plan, b, a, ladder);
    println!(
        "plan: {} chains / {} stage applications -> {} unique nodes",
        plan.num_chains(),
        plan.total_stages(),
        plan.unique_nodes()
    );

    let runner = EngineRunner::new(&engine, &train_ds, &test_ds, STAGE_STEPS, 42, false);
    let factory = || match Engine::new(coc::DEFAULT_ARTIFACTS) {
        Ok(e) => Ok(EngineRunner::new(e, &train_ds, &test_ds, STAGE_STEPS, 42, false)),
        Err(e) => Err(e),
    };
    let opts = ExecOpts {
        jobs: 1,
        cache_dir: Some(std::env::temp_dir().join("coc_pairwise_example_cache")),
        ..Default::default()
    };
    let run = plan.execute(&base, &runner, &opts, &factory)?;

    let lab_ab = format!("{}{}", a.letter(), b.letter());
    let lab_ba = format!("{}{}", b.letter(), a.letter());
    let ab: Vec<_> = run.points.iter().filter(|p| p.label == lab_ab).cloned().collect();
    let ba: Vec<_> = run.points.iter().filter(|p| p.label == lab_ba).cloned().collect();
    for (tag, pts) in [("AB", &ab), ("BA", &ba)] {
        for p in pts.iter() {
            println!(
                "  {} {:<10} acc {:>6.2}%  BitOpsCR {:>8.1}x",
                tag,
                p.config,
                p.measurement.accuracy * 100.0,
                p.measurement.bitops_cr
            );
        }
    }
    let sab = stats::frontier_score(&ab.iter().map(|p| p.xy()).collect::<Vec<_>>());
    let sba = stats::frontier_score(&ba.iter().map(|p| p.xy()).collect::<Vec<_>>());
    let (w1, w2) = if sab >= sba { (a, b) } else { (b, a) };
    println!(
        "frontier scores: {}{}={:.4}  {}{}={:.4}  ->  apply {} before {}",
        a.letter(),
        b.letter(),
        sab,
        b.letter(),
        a.letter(),
        sba,
        w1.letter(),
        w2.letter()
    );
    Ok(())
}
