//! Pairwise-order study in miniature (paper §3): measure one pair of
//! compression techniques in both orders and print which order's Pareto
//! frontier dominates.
//!
//!     cargo run --release --example pairwise_order [-- PQ]
//!
//! The argument names the pair by letters (default PQ = prune/quantize;
//! fastest pair since neither trains a student from scratch).

use anyhow::{anyhow, Result};

use coc::chain::{StageCtx, Technique};
use coc::data::{Dataset, DatasetKind};
use coc::models::Manifest;
use coc::runtime::Engine;
use coc::sweep;
use coc::train::{self, TrainOpts};
use coc::util::stats;

fn main() -> Result<()> {
    let pair = std::env::args().nth(1).unwrap_or_else(|| "PQ".to_string());
    let mut letters = pair.chars();
    let a = letters
        .next()
        .and_then(Technique::from_letter)
        .ok_or_else(|| anyhow!("bad pair `{pair}`"))?;
    let b = letters
        .next()
        .and_then(Technique::from_letter)
        .ok_or_else(|| anyhow!("bad pair `{pair}`"))?;

    let engine = Engine::new(coc::DEFAULT_ARTIFACTS)?;
    let manifest = Manifest::load(coc::DEFAULT_ARTIFACTS)?;
    let arch = manifest.arch("mini_resnet")?;
    let train_ds = Dataset::generate(DatasetKind::SynthC10, 512, 42, 0);
    let test_ds = Dataset::generate(DatasetKind::SynthC10, 128, 42, 1);

    println!("training base model...");
    let mut base = train::init_state(&engine, arch, 42)?;
    train::train(
        &engine,
        &mut base,
        &train_ds,
        None,
        &TrainOpts { steps: 150, ..Default::default() },
    )?;

    let ctx = StageCtx {
        engine: &engine,
        train: &train_ds,
        test: &test_ds,
        base_steps: 100,
        seed: 42,
        verbose: false,
    };
    let ladder = 3;
    println!("sweeping {}{} and {}{} ...", a.letter(), b.letter(), b.letter(), a.letter());
    let ab = sweep::pairwise_points(&base, a, b, &ctx, ladder)?;
    let ba = sweep::pairwise_points(&base, b, a, &ctx, ladder)?;

    for (tag, pts) in [("AB", &ab), ("BA", &ba)] {
        for p in pts.iter() {
            println!(
                "  {} {:<10} acc {:>6.2}%  BitOpsCR {:>8.1}x",
                tag,
                p.config,
                p.measurement.accuracy * 100.0,
                p.measurement.bitops_cr
            );
        }
    }
    let sab = stats::frontier_score(&ab.iter().map(|p| p.xy()).collect::<Vec<_>>());
    let sba = stats::frontier_score(&ba.iter().map(|p| p.xy()).collect::<Vec<_>>());
    let (w1, w2) = if sab >= sba { (a, b) } else { (b, a) };
    println!(
        "frontier scores: {}{}={:.4}  {}{}={:.4}  ->  apply {} before {}",
        a.letter(),
        b.letter(),
        sab,
        b.letter(),
        a.letter(),
        sba,
        w1.letter(),
        w2.letter()
    );
    Ok(())
}
