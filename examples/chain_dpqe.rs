//! End-to-end driver (the repo's headline validation): run the paper's
//! optimal sequence D -> P -> Q -> E on MiniResNet / SynthC10, logging the
//! per-stage loss curves, accuracy and compression ratios — the Fig 15
//! waterfall for one model.
//!
//!     make artifacts && cargo run --release --example chain_dpqe
//!
//! Expect (default budget): a base model in the 80-95% accuracy band, then
//! each stage multiplying BitOpsCR (distill ~4-8x, prune ~2-4x, quantize
//! ~16-128x, early-exit ~1.3-3x) at a small accuracy cost, landing at a
//! two-to-three-orders-of-magnitude total — the paper's 100-1000x claim
//! scaled to this testbed.  The run is recorded in EXPERIMENTS.md.

use anyhow::Result;

use coc::chain::{stages, Chain, StageCtx};
use coc::data::{Dataset, DatasetKind};
use coc::metrics::Measurement;
use coc::models::{Accountant, Manifest};
use coc::runtime::Engine;
use coc::train::{self, TrainOpts};

fn main() -> Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(220);

    let engine = Engine::new(coc::DEFAULT_ARTIFACTS)?;
    let manifest = Manifest::load(coc::DEFAULT_ARTIFACTS)?;
    let arch = manifest.arch("mini_resnet")?;

    let train_ds = Dataset::generate(DatasetKind::SynthC10, 1024, 42, 0);
    let test_ds = Dataset::generate(DatasetKind::SynthC10, 256, 42, 1);

    println!("=== base training (fp32 teacher) ===");
    let mut state = train::init_state(&engine, arch.clone(), 42)?;
    let opts = TrainOpts { steps: steps * 3 / 2, log_every: 50, ..Default::default() };
    train::train(&engine, &mut state, &train_ds, None, &opts)?;
    let base = Measurement::take(&engine, &state, &test_ds)?;
    let base_bitops = Accountant::baseline_bitops(&arch);
    println!(
        "base: acc {:.2}%  {:.3e} BitOps/inference",
        base.accuracy * 100.0,
        base_bitops
    );

    let ctx = StageCtx {
        engine: &engine,
        train: &train_ds,
        test: &test_ds,
        base_steps: steps,
        seed: 42,
        verbose: true,
    };
    let chain = Chain::new()
        .push(Box::new(stages::Distill { width: 0.5, ..Default::default() }))
        .push(Box::new(stages::Prune { ratio: 0.4, ..Default::default() }))
        .push(Box::new(stages::Quantize { bits_w: 1.0, bits_a: 8.0, ..Default::default() }))
        .push(Box::new(stages::EarlyExit { threshold: 0.8, ..Default::default() }));

    println!("=== chain {} ===", chain.sequence_letters());
    let reports = chain.run(&mut state, &ctx)?;

    println!("\nstage waterfall (paper Fig 15 analog):");
    println!("{:<28} {:>8} {:>12} {:>10}", "stage", "acc", "BitOpsCR", "CR");
    println!("{:<28} {:>7.2}% {:>11.1}x {:>9.1}x", "base(fp32)", base.accuracy * 100.0, 1.0, 1.0);
    for r in &reports {
        println!(
            "{:<28} {:>7.2}% {:>11.1}x {:>9.1}x",
            r.stage,
            r.measurement.accuracy * 100.0,
            r.measurement.bitops_cr,
            r.measurement.storage_cr
        );
    }
    let last = &reports.last().unwrap().measurement;
    println!(
        "\nDPQE total: acc {:.2}% ({:+.2}%)  BitOpsCR {:.0}x  CR {:.0}x  (exits: {:.0}%/{:.0}%)",
        last.accuracy * 100.0,
        (last.accuracy - base.accuracy) * 100.0,
        last.bitops_cr,
        last.storage_cr,
        last.exit_probs.0 * 100.0,
        last.exit_probs.1 * 100.0
    );
    let st = engine.stats();
    println!(
        "runtime: {} executes, {:.1}s XLA, {:.2}s upload, {:.2}s download",
        st.executions,
        st.execute_ns as f64 / 1e9,
        st.upload_ns as f64 / 1e9,
        st.download_ns as f64 / 1e9
    );
    Ok(())
}
